//! Event file formats.
//!
//! Table 1 of the paper surveys event-processing libraries by their
//! native file I/O. This module implements real, publicly specified
//! formats end-to-end (encode + decode) rather than binding vendor SDKs:
//!
//! * [`aedat`] — AEDAT 3.1 (Inivation), packet-framed polarity events;
//! * [`aedat2`] — AEDAT 2.0 (jAER), big-endian address/timestamp pairs;
//! * [`evt2`] — Prophesee EVT 2.0, 32-bit words with TIME_HIGH state;
//! * [`evt3`] — Prophesee EVT 3.0, 16-bit words with vectorized runs;
//! * [`dat`] — Prophesee DAT, fixed 8-byte records;
//! * [`raw`] — this library's packed 64-bit format (fastest, lossless);
//! * [`text`] — human-readable CSV (`x,y,p,t` per line).
//!
//! The paper's `.aedat4` container is flatbuffers+lz4; per DESIGN.md
//! §Substitutions we cover the same decode-to-stream code path with the
//! fully specified AEDAT 3.1 instead.
//!
//! All codecs implement [`EventCodec`]; [`detect_format`] sniffs
//! magic bytes, and [`read_events_auto`] is the "open anything" helper
//! the CLI uses. For O(chunk)-memory streaming I/O, [`streaming`]
//! wraps every codec in an incremental decoder/encoder pair used by
//! the [`crate::stream`] sources and sinks. The per-word decode loops
//! for the packed binary formats live in [`simd`], shared by the batch
//! and streaming decoders, with explicit SSE2 fast paths behind the
//! `simd` cargo feature.

pub mod aedat;
pub mod aedat2;
pub mod dat;
pub mod evt2;
pub mod evt3;
pub mod raw;
pub mod simd;
pub mod streaming;
pub mod text;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::aer::{Event, Resolution};

/// A bidirectional event codec.
///
/// Codecs are stateless objects; stream state (e.g. EVT2's TIME_HIGH)
/// lives inside the encode/decode call.
pub trait EventCodec {
    /// Short identifier, also the conventional file extension.
    fn name(&self) -> &'static str;

    /// Serialize `events` (timestamps must be non-decreasing) for a
    /// sensor of geometry `res`.
    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()>;

    /// Deserialize a full stream. Returns the events and the sensor
    /// geometry if the format records one (otherwise `res` is inferred
    /// as the bounding box rounded up).
    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)>;
}

/// Known formats, in sniffing order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Aedat,
    Aedat2,
    Dat,
    Evt2,
    Evt3,
    Raw,
    Text,
}

impl Format {
    /// All formats (for registries and round-trip property tests).
    pub const ALL: [Format; 7] = [
        Format::Aedat,
        Format::Aedat2,
        Format::Dat,
        Format::Evt2,
        Format::Evt3,
        Format::Raw,
        Format::Text,
    ];

    /// The codec object for this format.
    pub fn codec(&self) -> Box<dyn EventCodec> {
        match self {
            Format::Aedat => Box::new(aedat::Aedat31),
            Format::Aedat2 => Box::new(aedat2::Aedat2),
            Format::Dat => Box::new(dat::Dat),
            Format::Evt2 => Box::new(evt2::Evt2),
            Format::Evt3 => Box::new(evt3::Evt3),
            Format::Raw => Box::new(raw::RawPacked),
            Format::Text => Box::new(text::TextCsv),
        }
    }

    /// Guess from a file extension (`"aedat"`, `"evt2"`, …).
    pub fn from_extension(ext: &str) -> Option<Format> {
        match ext.to_ascii_lowercase().as_str() {
            "aedat" | "aedat3" => Some(Format::Aedat),
            "aedat2" => Some(Format::Aedat2),
            "dat" => Some(Format::Dat),
            "evt2" | "raw2" => Some(Format::Evt2),
            "evt3" | "raw3" => Some(Format::Evt3),
            "aeraw" | "bin" => Some(Format::Raw),
            "csv" | "txt" => Some(Format::Text),
            _ => None,
        }
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Format::Aedat => "aedat3.1",
            Format::Aedat2 => "aedat2.0",
            Format::Dat => "dat",
            Format::Evt2 => "evt2",
            Format::Evt3 => "evt3",
            Format::Raw => "raw",
            Format::Text => "text",
        };
        f.write_str(s)
    }
}

/// Sniff the format from the first bytes of a stream.
pub fn detect_format(prefix: &[u8]) -> Option<Format> {
    if prefix.starts_with(b"#!AER-DAT3.1") {
        return Some(Format::Aedat);
    }
    if prefix.starts_with(b"#!AER-DAT2.0") {
        return Some(Format::Aedat2);
    }
    if prefix.starts_with(raw::MAGIC) {
        return Some(Format::Raw);
    }
    if prefix.starts_with(b"% evt 2.0") || prefix.starts_with(b"% evt 2.1") {
        return Some(Format::Evt2);
    }
    if prefix.starts_with(b"% evt 3.0") {
        return Some(Format::Evt3);
    }
    if prefix.starts_with(b"% DAT") {
        return Some(Format::Dat);
    }
    // Text: printable ASCII with commas in the first line.
    if let Ok(s) = std::str::from_utf8(prefix) {
        let first = s.lines().next().unwrap_or("");
        if first.starts_with('#') || first.split(',').count() == 4 {
            return Some(Format::Text);
        }
    }
    None
}

/// Read a whole event file, sniffing the format from content first and
/// the extension second.
pub fn read_events_auto(path: &Path) -> Result<(Vec<Event>, Resolution, Format)> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let sniffed = detect_format(&bytes[..bytes.len().min(64)]);
    let by_ext = path.extension().and_then(|e| e.to_str()).and_then(Format::from_extension);
    let format = match sniffed.or(by_ext) {
        Some(f) => f,
        None => bail!("cannot determine event format of {}", path.display()),
    };
    let (events, res) = format
        .codec()
        .decode(&mut &bytes[..])
        .with_context(|| format!("decoding {} as {format}", path.display()))?;
    Ok((events, res, format))
}

/// Write a whole event file in the given format.
pub fn write_events(path: &Path, events: &[Event], res: Resolution, format: Format) -> Result<()> {
    let mut file = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    format.codec().encode(events, res, &mut file)?;
    Ok(())
}

/// Smallest resolution covering every event in the stream (fallback when
/// a format does not record geometry).
pub(crate) fn bounding_resolution(events: &[Event]) -> Resolution {
    let (mut w, mut h) = (1u16, 1u16);
    for ev in events {
        w = w.max(ev.x + 1);
        h = h.max(ev.y + 1);
    }
    Resolution::new(w, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    /// Every codec round-trips an arbitrary (valid) stream losslessly.
    #[test]
    fn all_formats_roundtrip() {
        let events = synthetic_events(2000, 346, 260);
        let res = Resolution::DAVIS_346;
        for format in Format::ALL {
            let codec = format.codec();
            let mut buf = Vec::new();
            codec.encode(&events, res, &mut buf).unwrap_or_else(|e| panic!("{format}: {e}"));
            let (decoded, dres) =
                codec.decode(&mut &buf[..]).unwrap_or_else(|e| panic!("{format}: {e}"));
            assert_eq!(decoded, events, "format {format} round-trip mismatch");
            assert_eq!(dres, res, "format {format} resolution mismatch");
        }
    }

    #[test]
    fn all_formats_roundtrip_empty() {
        let res = Resolution::new(64, 64);
        for format in Format::ALL {
            let codec = format.codec();
            let mut buf = Vec::new();
            codec.encode(&[], res, &mut buf).unwrap();
            let (decoded, _) = codec.decode(&mut &buf[..]).unwrap();
            assert!(decoded.is_empty(), "format {format} produced phantom events");
        }
    }

    #[test]
    fn detection_from_encoded_bytes() {
        let events = synthetic_events(50, 64, 64);
        let res = Resolution::new(64, 64);
        for format in Format::ALL {
            let mut buf = Vec::new();
            format.codec().encode(&events, res, &mut buf).unwrap();
            assert_eq!(
                detect_format(&buf[..buf.len().min(64)]),
                Some(format),
                "sniffing {format}"
            );
        }
    }

    #[test]
    fn extension_mapping() {
        assert_eq!(Format::from_extension("AEDAT"), Some(Format::Aedat));
        assert_eq!(Format::from_extension("csv"), Some(Format::Text));
        assert_eq!(Format::from_extension("xyz"), None);
    }

    #[test]
    fn bounding_resolution_covers_all() {
        let events = vec![crate::aer::Event::on(10, 5, 0), crate::aer::Event::off(3, 20, 1)];
        let res = bounding_resolution(&events);
        assert_eq!((res.width, res.height), (11, 21));
    }
}
