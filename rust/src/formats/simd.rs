//! Shared word-level decoders for the packed binary formats, with
//! optional explicit-SIMD fast paths.
//!
//! The batch codecs ([`super::raw`], [`super::evt2`], [`super::evt3`])
//! and the incremental [`super::streaming`] decoder used to each carry
//! their own copy of the per-word decode loop. This module is the single
//! home for those loops, so the hot path is written (and vectorized)
//! once:
//!
//! * **Raw** is stateless — one `u64` load plus a shift/mask ladder per
//!   event. The loop is four-way unrolled straight-line code the
//!   compiler auto-vectorizes; no explicit intrinsics are needed.
//! * **EVT2** and **EVT3** are state machines, which defeats naive
//!   vectorization — but real streams are dominated by long runs of
//!   *event* words (CD words in EVT2, `ADDR_X` words in EVT3) between
//!   sparse state words. The `simd` feature adds block kernels — SSE2
//!   on x86_64, NEON on aarch64, mirroring each other block-for-block —
//!   that classify a whole block of words at once: if every word in the
//!   block is an event word, its fields are extracted lane-parallel
//!   with the current state applied uniformly; otherwise the block
//!   falls back to the scalar machine one word at a time, preserving
//!   exact state and error semantics.
//!
//! The scalar decoders are always compiled (and are the only path on
//! other targets or without the `simd` feature); the equivalence tests
//! here and in `rust/tests/streaming_formats.rs` fuzz-compare the two
//! word-for-word, including at word-splitting chunk boundaries.

use anyhow::{bail, Result};

use crate::aer::{packed, Event, Polarity};

use super::{aedat2, evt2, evt3};

/// The explicit-SIMD kernel module for the current target, when one
/// exists: SSE2 (baseline on x86_64) or NEON (baseline on aarch64).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
use x86 as kern;
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
use neon as kern;

/// The EVT3 decoder state machine (the batch decoder's local variables,
/// lifted into a struct so it survives chunk breaks in the streaming
/// decoder).
#[derive(Debug, Clone)]
pub struct Evt3State {
    y: u16,
    time_low: u64,
    time_high: u64,
    time_epoch: u64,
    have_time: bool,
    vect_base_x: u16,
    vect_pol: Polarity,
}

impl Default for Evt3State {
    fn default() -> Self {
        Evt3State {
            y: 0,
            time_low: 0,
            time_high: 0,
            time_epoch: 0,
            have_time: false,
            vect_base_x: 0,
            vect_pol: Polarity::Off,
        }
    }
}

impl Evt3State {
    /// The full 64-bit timestamp of the current time state.
    #[inline]
    fn t(&self) -> u64 {
        self.time_epoch | (self.time_high << 12) | self.time_low
    }
}

// ---------------------------------------------------------------- raw

/// Decode complete packed-raw words (`bytes.len()` must be a multiple
/// of 8) into events. Stateless and infallible: every 64-bit pattern is
/// a valid packed event.
pub fn decode_raw_words(bytes: &[u8], out: &mut Vec<Event>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.reserve(bytes.len() / 8);
    // Four independent unpacks per iteration: no cross-word state, so
    // the shift/mask ladder is straight-line code the compiler turns
    // into vector loads and shuffles.
    let mut blocks = bytes.chunks_exact(32);
    for block in &mut blocks {
        let w0 = u64::from_le_bytes(block[0..8].try_into().unwrap());
        let w1 = u64::from_le_bytes(block[8..16].try_into().unwrap());
        let w2 = u64::from_le_bytes(block[16..24].try_into().unwrap());
        let w3 = u64::from_le_bytes(block[24..32].try_into().unwrap());
        out.push(packed::unpack(w0));
        out.push(packed::unpack(w1));
        out.push(packed::unpack(w2));
        out.push(packed::unpack(w3));
    }
    decode_raw_words_scalar(blocks.remainder(), out);
}

/// Plain one-word-at-a-time reference decoder for packed raw.
pub fn decode_raw_words_scalar(bytes: &[u8], out: &mut Vec<Event>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    for word in bytes.chunks_exact(8) {
        out.push(packed::unpack(u64::from_le_bytes(word.try_into().unwrap())));
    }
}

// --------------------------------------------------------------- evt2

/// Decode complete EVT2 words (`bytes.len()` must be a multiple of 4),
/// carrying the `TIME_HIGH` state across calls.
pub fn decode_evt2_words(
    bytes: &[u8],
    time_high: &mut Option<u64>,
    out: &mut Vec<Event>,
) -> Result<()> {
    debug_assert_eq!(bytes.len() % 4, 0);
    #[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let mut off = 0;
        while off + 16 <= bytes.len() {
            if kern::evt2_block4(&bytes[off..off + 16], *time_high, out) {
                off += 16;
            } else {
                // The block holds a state word (TIME_HIGH, trigger, or
                // an unknown type) or no TIME_HIGH has been seen yet:
                // run the scalar machine for one word — which may
                // update the state or bail — then retry SIMD.
                decode_evt2_words_scalar(&bytes[off..off + 4], time_high, out)?;
                off += 4;
            }
        }
        return decode_evt2_words_scalar(&bytes[off..], time_high, out);
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    decode_evt2_words_scalar(bytes, time_high, out)
}

/// Find the value of the *last* `TIME_HIGH` word in a complete-word
/// EVT2 slice, or `None` if the slice carries no `TIME_HIGH` at all.
///
/// `TIME_HIGH` fully resets the EVT2 decoder's only state, so this is
/// exactly the entry state the bytes *after* this slice decode under —
/// the cut-point pre-scan for parallel EVT2 decode
/// ([`SplitPoints::ScanBoundaries`](super::streaming::SplitPoints)).
/// Scans backwards (state words are sparse but regular, so the scan
/// usually touches a few dozen words); with `simd`, 4-lane blocks are
/// classified at once and only a matching block is scanned per-word.
pub fn evt2_scan_last_time_high(bytes: &[u8]) -> Option<u64> {
    debug_assert_eq!(bytes.len() % 4, 0);
    let mut end = bytes.len();
    #[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
    while end >= 16 {
        if kern::evt2_any_time_high(&bytes[end - 16..end]) {
            break; // the match is inside this block: finish per-word
        }
        end -= 16;
    }
    for word in bytes[..end].chunks_exact(4).rev() {
        let w = u32::from_le_bytes(word.try_into().unwrap());
        if w >> 28 == evt2::TYPE_TIME_HIGH {
            return Some((w & 0x0FFF_FFFF) as u64);
        }
    }
    None
}

/// Scalar reference EVT2 word decoder (always compiled; the SIMD path
/// is fuzz-compared against it word-for-word).
pub fn decode_evt2_words_scalar(
    bytes: &[u8],
    time_high: &mut Option<u64>,
    out: &mut Vec<Event>,
) -> Result<()> {
    debug_assert_eq!(bytes.len() % 4, 0);
    for word in bytes.chunks_exact(4) {
        let w = u32::from_le_bytes(word.try_into().unwrap());
        match w >> 28 {
            evt2::TYPE_TIME_HIGH => *time_high = Some((w & 0x0FFF_FFFF) as u64),
            ty @ (evt2::TYPE_CD_OFF | evt2::TYPE_CD_ON) => {
                let Some(th) = *time_high else {
                    bail!("evt2: CD word before any TIME_HIGH");
                };
                out.push(Event {
                    t: (th << 6) | ((w >> 22) & 0x3F) as u64,
                    x: ((w >> 11) & 0x7FF) as u16,
                    y: (w & 0x7FF) as u16,
                    p: Polarity::from_bool(ty == evt2::TYPE_CD_ON),
                });
            }
            evt2::TYPE_EXT_TRIGGER => {} // triggers carry no CD payload
            _ => {}                      // forward-compatible: ignore unknown types
        }
    }
    Ok(())
}

// --------------------------------------------------------------- evt3

/// Decode complete EVT3 words (`bytes.len()` must be a multiple of 2),
/// advancing the state machine across calls.
pub fn decode_evt3_words(bytes: &[u8], st: &mut Evt3State, out: &mut Vec<Event>) -> Result<()> {
    debug_assert_eq!(bytes.len() % 2, 0);
    #[cfg(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let mut off = 0;
        while off + 16 <= bytes.len() {
            // ADDR_X words read the (y, time) state but never modify
            // it, so a block of eight decodes with one shared (t, y).
            let consumed =
                st.have_time && kern::evt3_block8(&bytes[off..off + 16], st.t(), st.y, out);
            if consumed {
                off += 16;
            } else {
                decode_evt3_words_scalar(&bytes[off..off + 2], st, out)?;
                off += 2;
            }
        }
        return decode_evt3_words_scalar(&bytes[off..], st, out);
    }
    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    decode_evt3_words_scalar(bytes, st, out)
}

// --------------------------------------------- aedat2 / dat (scalar)

/// Decode complete AEDAT 2.0 records (8-byte big-endian address+time
/// pairs; `bytes.len()` must be a multiple of 8). Stateless.
pub fn decode_aedat2_words(bytes: &[u8], out: &mut Vec<Event>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.reserve(bytes.len() / 8);
    for rec in bytes.chunks_exact(8) {
        let addr = u32::from_be_bytes(rec[0..4].try_into().unwrap());
        let t = u32::from_be_bytes(rec[4..8].try_into().unwrap()) as u64;
        out.push(Event {
            t,
            x: ((addr >> aedat2::X_SHIFT) & aedat2::COORD_MASK) as u16,
            y: ((addr >> aedat2::Y_SHIFT) & aedat2::COORD_MASK) as u16,
            p: Polarity::from_bool(addr & 1 == 1),
        });
    }
}

/// Decode complete Prophesee DAT CD records (8-byte little-endian
/// time+data pairs; `bytes.len()` must be a multiple of 8). Stateless.
pub fn decode_dat_words(bytes: &[u8], out: &mut Vec<Event>) {
    debug_assert_eq!(bytes.len() % 8, 0);
    out.reserve(bytes.len() / 8);
    for rec in bytes.chunks_exact(8) {
        let t = u32::from_le_bytes(rec[0..4].try_into().unwrap()) as u64;
        let data = u32::from_le_bytes(rec[4..8].try_into().unwrap());
        out.push(Event {
            t,
            x: (data & 0x3FFF) as u16,
            y: ((data >> 14) & 0x3FFF) as u16,
            p: Polarity::from_bool((data >> 28) & 0xF != 0),
        });
    }
}

/// Scalar reference EVT3 word decoder (always compiled; the SIMD path
/// is fuzz-compared against it word-for-word).
pub fn decode_evt3_words_scalar(
    bytes: &[u8],
    st: &mut Evt3State,
    out: &mut Vec<Event>,
) -> Result<()> {
    debug_assert_eq!(bytes.len() % 2, 0);
    for wbytes in bytes.chunks_exact(2) {
        let w = u16::from_le_bytes(wbytes.try_into().unwrap());
        let payload = w & 0x0FFF;
        match w >> 12 {
            evt3::TY_ADDR_Y => st.y = payload & 0x7FF,
            evt3::TY_TIME_HIGH => {
                let new_high = payload as u64;
                if st.have_time && new_high < st.time_high {
                    st.time_epoch += 1 << 24; // 24-bit rollover
                }
                st.time_high = new_high;
                st.time_low = 0;
                st.have_time = true;
            }
            evt3::TY_TIME_LOW => {
                st.time_low = payload as u64;
                st.have_time = true;
            }
            evt3::TY_ADDR_X => {
                if !st.have_time {
                    bail!("evt3: CD word before any time word");
                }
                out.push(Event {
                    t: st.t(),
                    x: payload & 0x7FF,
                    y: st.y,
                    p: Polarity::from_bool(payload & 0x800 != 0),
                });
            }
            evt3::TY_VECT_BASE_X => {
                st.vect_base_x = payload & 0x7FF;
                st.vect_pol = Polarity::from_bool(payload & 0x800 != 0);
            }
            evt3::TY_VECT_12 | evt3::TY_VECT_8 => {
                if !st.have_time {
                    bail!("evt3: vector word before any time word");
                }
                let width = if w >> 12 == evt3::TY_VECT_12 { 12 } else { 8 };
                let t = st.t();
                let mut mask = payload & ((1u16 << width) - 1);
                while mask != 0 {
                    let bit = mask.trailing_zeros() as u16;
                    out.push(Event { t, x: st.vect_base_x + bit, y: st.y, p: st.vect_pol });
                    mask &= mask - 1;
                }
                // Per spec the base advances past the vector window.
                st.vect_base_x += width;
            }
            _ => {} // EXT_TRIGGER, OTHERS, CONTINUED: skipped
        }
    }
    Ok(())
}

// ------------------------------------------------------- SSE2 kernels

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! SSE2 block kernels. SSE2 is baseline on x86_64, so there is no
    //! runtime feature detection: the kernels compile whenever the
    //! `simd` feature targets x86_64.

    use core::arch::x86_64::*;

    use crate::aer::{Event, Polarity};
    use crate::formats::{evt2, evt3};

    /// Decode a 16-byte block of four EVT2 words iff all four are CD
    /// events. Returns `true` when the block was consumed.
    #[inline]
    pub(super) fn evt2_block4(block: &[u8], time_high: Option<u64>, out: &mut Vec<Event>) -> bool {
        debug_assert_eq!(block.len(), 16);
        let Some(th) = time_high else {
            return false; // a CD word here must error: scalar handles it
        };
        unsafe {
            let v = _mm_loadu_si128(block.as_ptr() as *const __m128i);
            // CD words are exactly the types 0x0/0x1, i.e. the whole
            // word is < 0x2000_0000 *unsigned*. SSE2 only compares
            // signed, so bias both sides by 2^31 (XOR with `i32::MIN`
            // turns an unsigned order into a signed one).
            let bias = _mm_set1_epi32(i32::MIN);
            let lim = _mm_set1_epi32(0x2000_0000u32 as i32 ^ i32::MIN);
            let cd = _mm_cmplt_epi32(_mm_xor_si128(v, bias), lim);
            if _mm_movemask_epi8(cd) != 0xFFFF {
                return false;
            }
            // All four lanes are CD: extract every field lane-parallel.
            let t6 = _mm_and_si128(_mm_srli_epi32::<22>(v), _mm_set1_epi32(0x3F));
            let xs = _mm_and_si128(_mm_srli_epi32::<11>(v), _mm_set1_epi32(0x7FF));
            let ys = _mm_and_si128(v, _mm_set1_epi32(0x7FF));
            let ps = _mm_srli_epi32::<28>(v); // 0x0 = OFF, 0x1 = ON
            let mut t6a = [0u32; 4];
            let mut xsa = [0u32; 4];
            let mut ysa = [0u32; 4];
            let mut psa = [0u32; 4];
            _mm_storeu_si128(t6a.as_mut_ptr() as *mut __m128i, t6);
            _mm_storeu_si128(xsa.as_mut_ptr() as *mut __m128i, xs);
            _mm_storeu_si128(ysa.as_mut_ptr() as *mut __m128i, ys);
            _mm_storeu_si128(psa.as_mut_ptr() as *mut __m128i, ps);
            for i in 0..4 {
                out.push(Event {
                    t: (th << 6) | t6a[i] as u64,
                    x: xsa[i] as u16,
                    y: ysa[i] as u16,
                    p: Polarity::from_bool(psa[i] == 1),
                });
            }
        }
        true
    }

    /// Decode a 16-byte block of eight EVT3 words iff all eight are
    /// `ADDR_X` events (which read but never modify the decoder state,
    /// so the shared `(t, y)` applies to the whole block). The caller
    /// guarantees `have_time`. Returns `true` when consumed.
    #[inline]
    pub(super) fn evt3_block8(block: &[u8], t: u64, y: u16, out: &mut Vec<Event>) -> bool {
        debug_assert_eq!(block.len(), 16);
        unsafe {
            let v = _mm_loadu_si128(block.as_ptr() as *const __m128i);
            let ty = _mm_srli_epi16::<12>(v);
            let addr_x = _mm_cmpeq_epi16(ty, _mm_set1_epi16(evt3::TY_ADDR_X as i16));
            if _mm_movemask_epi8(addr_x) != 0xFFFF {
                return false;
            }
            let xs = _mm_and_si128(v, _mm_set1_epi16(0x7FF));
            let ps = _mm_and_si128(_mm_srli_epi16::<11>(v), _mm_set1_epi16(1));
            let mut xsa = [0u16; 8];
            let mut psa = [0u16; 8];
            _mm_storeu_si128(xsa.as_mut_ptr() as *mut __m128i, xs);
            _mm_storeu_si128(psa.as_mut_ptr() as *mut __m128i, ps);
            for i in 0..8 {
                out.push(Event {
                    t,
                    x: xsa[i],
                    y,
                    p: Polarity::from_bool(psa[i] == 1),
                });
            }
        }
        true
    }

    /// `true` iff any of the four EVT2 words in the 16-byte block is a
    /// `TIME_HIGH` word — the cut-point pre-scan's block classifier.
    #[inline]
    pub(super) fn evt2_any_time_high(block: &[u8]) -> bool {
        debug_assert_eq!(block.len(), 16);
        unsafe {
            let v = _mm_loadu_si128(block.as_ptr() as *const __m128i);
            let ty = _mm_srli_epi32::<28>(v);
            let th = _mm_cmpeq_epi32(ty, _mm_set1_epi32(evt2::TYPE_TIME_HIGH as i32));
            _mm_movemask_epi8(th) != 0
        }
    }
}

// ------------------------------------------------------- NEON kernels

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    //! NEON block kernels, mirroring the SSE2 module block-for-block.
    //! Advanced SIMD is baseline on aarch64, so — like SSE2 on x86_64 —
    //! there is no runtime feature detection: the kernels compile
    //! whenever the `simd` feature targets aarch64. One asymmetry works
    //! in our favor: NEON compares unsigned natively (`vcltq_u32`), so
    //! the EVT2 classifier needs no sign-bias trick.

    use core::arch::aarch64::*;

    use crate::aer::{Event, Polarity};
    use crate::formats::{evt2, evt3};

    /// Decode a 16-byte block of four EVT2 words iff all four are CD
    /// events. Returns `true` when the block was consumed.
    #[inline]
    pub(super) fn evt2_block4(block: &[u8], time_high: Option<u64>, out: &mut Vec<Event>) -> bool {
        debug_assert_eq!(block.len(), 16);
        let Some(th) = time_high else {
            return false; // a CD word here must error: scalar handles it
        };
        unsafe {
            let v = vld1q_u32(block.as_ptr() as *const u32);
            // CD words are exactly the types 0x0/0x1, i.e. the whole
            // word is < 0x2000_0000 unsigned.
            let cd = vcltq_u32(v, vdupq_n_u32(0x2000_0000));
            if vminvq_u32(cd) != u32::MAX {
                return false;
            }
            // All four lanes are CD: extract every field lane-parallel.
            let t6 = vandq_u32(vshrq_n_u32::<22>(v), vdupq_n_u32(0x3F));
            let xs = vandq_u32(vshrq_n_u32::<11>(v), vdupq_n_u32(0x7FF));
            let ys = vandq_u32(v, vdupq_n_u32(0x7FF));
            let ps = vshrq_n_u32::<28>(v); // 0x0 = OFF, 0x1 = ON
            let mut t6a = [0u32; 4];
            let mut xsa = [0u32; 4];
            let mut ysa = [0u32; 4];
            let mut psa = [0u32; 4];
            vst1q_u32(t6a.as_mut_ptr(), t6);
            vst1q_u32(xsa.as_mut_ptr(), xs);
            vst1q_u32(ysa.as_mut_ptr(), ys);
            vst1q_u32(psa.as_mut_ptr(), ps);
            for i in 0..4 {
                out.push(Event {
                    t: (th << 6) | t6a[i] as u64,
                    x: xsa[i] as u16,
                    y: ysa[i] as u16,
                    p: Polarity::from_bool(psa[i] == 1),
                });
            }
        }
        true
    }

    /// Decode a 16-byte block of eight EVT3 words iff all eight are
    /// `ADDR_X` events (which read but never modify the decoder state,
    /// so the shared `(t, y)` applies to the whole block). The caller
    /// guarantees `have_time`. Returns `true` when consumed.
    #[inline]
    pub(super) fn evt3_block8(block: &[u8], t: u64, y: u16, out: &mut Vec<Event>) -> bool {
        debug_assert_eq!(block.len(), 16);
        unsafe {
            let v = vld1q_u16(block.as_ptr() as *const u16);
            let ty = vshrq_n_u16::<12>(v);
            let addr_x = vceqq_u16(ty, vdupq_n_u16(evt3::TY_ADDR_X));
            if vminvq_u16(addr_x) != u16::MAX {
                return false;
            }
            let xs = vandq_u16(v, vdupq_n_u16(0x7FF));
            let ps = vandq_u16(vshrq_n_u16::<11>(v), vdupq_n_u16(1));
            let mut xsa = [0u16; 8];
            let mut psa = [0u16; 8];
            vst1q_u16(xsa.as_mut_ptr(), xs);
            vst1q_u16(psa.as_mut_ptr(), ps);
            for i in 0..8 {
                out.push(Event {
                    t,
                    x: xsa[i],
                    y,
                    p: Polarity::from_bool(psa[i] == 1),
                });
            }
        }
        true
    }

    /// `true` iff any of the four EVT2 words in the 16-byte block is a
    /// `TIME_HIGH` word — the cut-point pre-scan's block classifier.
    #[inline]
    pub(super) fn evt2_any_time_high(block: &[u8]) -> bool {
        debug_assert_eq!(block.len(), 16);
        unsafe {
            let v = vld1q_u32(block.as_ptr() as *const u32);
            let ty = vshrq_n_u32::<28>(v);
            let th = vceqq_u32(ty, vdupq_n_u32(evt2::TYPE_TIME_HIGH));
            vmaxvq_u32(th) != 0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Resolution;
    use crate::formats::{EventCodec, Format};
    use crate::testutil::synthetic_events_seeded;

    /// Encode events in `format`, strip the header, return body bytes.
    fn body_bytes(format: Format, events: &[Event]) -> Vec<u8> {
        let mut buf = Vec::new();
        format.codec().encode(events, Resolution::new(640, 480), &mut buf).unwrap();
        let (_, body) = crate::formats::evt2::split_percent_header(&buf);
        match format {
            Format::Raw => buf[16..].to_vec(),
            _ => body.to_vec(),
        }
    }

    #[test]
    fn evt2_dispatch_matches_scalar() {
        let events = synthetic_events_seeded(4000, 640, 480, 0x51D);
        let body = body_bytes(Format::Evt2, &events);
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        let (mut th_f, mut th_s) = (None, None);
        decode_evt2_words(&body, &mut th_f, &mut fast).unwrap();
        decode_evt2_words_scalar(&body, &mut th_s, &mut slow).unwrap();
        assert_eq!(fast, slow);
        assert_eq!(th_f, th_s);
        assert_eq!(fast, events);
    }

    #[test]
    fn evt3_dispatch_matches_scalar() {
        let events = synthetic_events_seeded(4000, 640, 480, 0xE3);
        let body = body_bytes(Format::Evt3, &events);
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        let (mut st_f, mut st_s) = (Evt3State::default(), Evt3State::default());
        decode_evt3_words(&body, &mut st_f, &mut fast).unwrap();
        decode_evt3_words_scalar(&body, &mut st_s, &mut slow).unwrap();
        assert_eq!(fast, slow);
        assert_eq!(fast, events);
    }

    #[test]
    fn raw_unrolled_matches_scalar() {
        let events = synthetic_events_seeded(1003, 640, 480, 0xAE);
        let body = body_bytes(Format::Raw, &events);
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        decode_raw_words(&body, &mut fast);
        decode_raw_words_scalar(&body, &mut slow);
        assert_eq!(fast, slow);
        assert_eq!(fast, events);
    }

    #[test]
    fn aedat2_and_dat_word_decoders_match_the_batch_codecs() {
        let events = synthetic_events_seeded(1500, 640, 480, 0xDA7);
        for (format, decode) in [
            (Format::Aedat2, decode_aedat2_words as fn(&[u8], &mut Vec<Event>)),
            (Format::Dat, decode_dat_words as fn(&[u8], &mut Vec<Event>)),
        ] {
            let mut buf = Vec::new();
            format.codec().encode(&events, Resolution::new(640, 480), &mut buf).unwrap();
            let body = match format {
                // AEDAT 2.0: '#' comment lines, then 8-byte records.
                Format::Aedat2 => {
                    let mut off = 0;
                    while off < buf.len() && buf[off] == b'#' {
                        off += buf[off..].iter().position(|&b| b == b'\n').unwrap() + 1;
                    }
                    buf[off..].to_vec()
                }
                // DAT: '%' header plus the 2-byte binary preamble.
                _ => {
                    let (_, body) = crate::formats::evt2::split_percent_header(&buf);
                    body[2..].to_vec()
                }
            };
            let mut out = Vec::new();
            decode(&body, &mut out);
            assert_eq!(out, events, "{format}");
        }
    }

    #[test]
    fn evt2_time_high_scan_matches_naive_backward_scan() {
        let events = synthetic_events_seeded(3000, 640, 480, 0x7157);
        let body = body_bytes(Format::Evt2, &events);
        // Every word-aligned prefix must agree with the one-word-at-a-
        // time reference, including prefixes with no TIME_HIGH at all.
        for end in (0..=body.len()).step_by(4) {
            let slice = &body[..end];
            let naive = slice.chunks_exact(4).rev().find_map(|w| {
                let w = u32::from_le_bytes(w.try_into().unwrap());
                (w >> 28 == evt2::TYPE_TIME_HIGH).then(|| (w & 0x0FFF_FFFF) as u64)
            });
            assert_eq!(evt2_scan_last_time_high(slice), naive, "prefix {end}");
        }
    }

    #[test]
    fn evt2_cd_before_time_high_errors_in_both_paths() {
        let cd = ((evt2::TYPE_CD_ON << 28) | (5 << 22) | (3 << 11) | 4u32).to_le_bytes();
        // Four CD words: enough to make a full SIMD block.
        let body: Vec<u8> = cd.iter().copied().cycle().take(16).collect();
        for decode in [decode_evt2_words, decode_evt2_words_scalar] {
            let err = decode(&body, &mut None, &mut Vec::new()).unwrap_err();
            assert!(format!("{err}").contains("before any TIME_HIGH"), "{err}");
        }
    }

    #[test]
    fn evt3_addr_x_before_time_errors_in_both_paths() {
        let w = ((evt3::TY_ADDR_X << 12) | 5u16).to_le_bytes();
        // Eight ADDR_X words: a full SIMD block with no time state.
        let body: Vec<u8> = w.iter().copied().cycle().take(16).collect();
        for decode in [decode_evt3_words, decode_evt3_words_scalar] {
            let err = decode(&body, &mut Evt3State::default(), &mut Vec::new()).unwrap_err();
            assert!(format!("{err}").contains("before any time word"), "{err}");
        }
    }
}
