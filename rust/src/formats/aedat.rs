//! AEDAT 3.1 (Inivation) — packet-framed polarity events.
//!
//! The format the DV ecosystem used before the flatbuffers-based AEDAT4:
//! an ASCII header terminated by `#End Of ASCII Header\r\n`, followed by
//! binary *packets*. Each packet has a 28-byte little-endian header
//!
//! ```text
//! i16 eventType      (1 = POLARITY_EVENT)
//! i16 eventSource
//! i32 eventSize      (8 bytes for polarity)
//! i32 eventTSOffset  (4: timestamp lives at byte 4 of the record)
//! i32 eventTSOverflow(upper 31-bit epoch of the 32-bit timestamps)
//! i32 eventCapacity
//! i32 eventNumber
//! i32 eventValid
//! ```
//!
//! and `eventNumber` 8-byte records: `u32 data | i32 timestamp(µs)`,
//! where `data` packs `bit0 = valid`, `bit1 = polarity`,
//! `bits 2..17 = y`, `bits 17..32 = x` (AEDAT 3.1 spec).
//!
//! Timestamps beyond 2^31 µs (~35.8 min) roll into `eventTSOverflow`,
//! which this codec handles on both sides.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::aer::{Event, Polarity, Resolution};

use super::EventCodec;

pub(super) const HEADER_END: &[u8] = b"#End Of ASCII Header\r\n";
pub(super) const POLARITY_EVENT: i16 = 1;
pub(super) const EVENT_SIZE: i32 = 8;
/// Events per packet when encoding (spec allows any; DV uses ~4096).
const PACKET_CAPACITY: usize = 4096;

/// The codec object.
pub struct Aedat31;

impl EventCodec for Aedat31 {
    fn name(&self) -> &'static str {
        "aedat"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        write!(
            w,
            "#!AER-DAT3.1\r\n#Format: RAW\r\n#Source 1: Davis346 [{}x{}]\r\n#Start-Time: 1970-01-01 00:00:00 (TZ+0000)\r\n",
            res.width, res.height
        )?;
        w.write_all(HEADER_END)?;

        let mut buf = Vec::with_capacity(28 + 8 * PACKET_CAPACITY);
        let mut chunk_start = 0usize;
        while chunk_start < events.len() {
            // A packet may not span a timestamp-overflow boundary: all
            // events in a packet share one eventTSOverflow epoch.
            let epoch = events[chunk_start].t >> 31;
            let mut end = (chunk_start + PACKET_CAPACITY).min(events.len());
            if let Some(split) =
                events[chunk_start..end].iter().position(|e| e.t >> 31 != epoch)
            {
                end = chunk_start + split;
            }
            let chunk = &events[chunk_start..end];
            chunk_start = end;

            buf.clear();
            let n = chunk.len() as i32;
            buf.extend_from_slice(&POLARITY_EVENT.to_le_bytes());
            buf.extend_from_slice(&0i16.to_le_bytes()); // source
            buf.extend_from_slice(&EVENT_SIZE.to_le_bytes());
            buf.extend_from_slice(&4i32.to_le_bytes()); // ts offset
            buf.extend_from_slice(&(epoch as i32).to_le_bytes());
            buf.extend_from_slice(&n.to_le_bytes()); // capacity
            buf.extend_from_slice(&n.to_le_bytes()); // number
            buf.extend_from_slice(&n.to_le_bytes()); // valid
            for ev in chunk {
                let data: u32 = 1 // valid bit
                    | (u32::from(ev.p.is_on()) << 1)
                    | ((ev.y as u32 & 0x7FFF) << 2)
                    | ((ev.x as u32 & 0x7FFF) << 17);
                let ts = (ev.t & 0x7FFF_FFFF) as u32;
                buf.extend_from_slice(&data.to_le_bytes());
                buf.extend_from_slice(&ts.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        if !bytes.starts_with(b"#!AER-DAT3.1") {
            bail!("aedat: missing #!AER-DAT3.1 signature");
        }
        let body_start = find(&bytes, HEADER_END)
            .context("aedat: missing '#End Of ASCII Header'")?
            + HEADER_END.len();

        // Geometry from the "#Source …[WxH]" header line, if present.
        let header_text = String::from_utf8_lossy(&bytes[..body_start]);
        let res = parse_geometry(&header_text);

        let mut events = Vec::new();
        let mut off = body_start;
        while off < bytes.len() {
            if bytes.len() - off < 28 {
                bail!("aedat: truncated packet header at byte {off}");
            }
            let h = &bytes[off..off + 28];
            let event_type = i16::from_le_bytes([h[0], h[1]]);
            let event_size = i32::from_le_bytes(h[4..8].try_into().unwrap());
            let ts_overflow = i32::from_le_bytes(h[12..16].try_into().unwrap()) as u64;
            let event_number = i32::from_le_bytes(h[20..24].try_into().unwrap());
            off += 28;
            if event_size <= 0 || event_number < 0 {
                bail!("aedat: corrupt packet header (size {event_size}, n {event_number})");
            }
            let payload = event_size as usize * event_number as usize;
            if bytes.len() - off < payload {
                bail!("aedat: truncated packet payload at byte {off}");
            }
            if event_type == POLARITY_EVENT && event_size == EVENT_SIZE {
                for rec in bytes[off..off + payload].chunks_exact(8) {
                    let data = u32::from_le_bytes(rec[0..4].try_into().unwrap());
                    if data & 1 == 0 {
                        continue; // invalidated event
                    }
                    let ts = u32::from_le_bytes(rec[4..8].try_into().unwrap()) as u64;
                    events.push(Event {
                        x: ((data >> 17) & 0x7FFF) as u16,
                        y: ((data >> 2) & 0x7FFF) as u16,
                        p: Polarity::from_bool(data & 2 != 0),
                        t: (ts_overflow << 31) | ts,
                    });
                }
            }
            // Unknown event types are skipped (spec: readers must ignore).
            off += payload;
        }
        let res = res.unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

/// Find the first occurrence of `needle` in `haystack` (also used by
/// the chunked [`super::streaming`] decoder to locate the header end).
pub(super) fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Parse `[WxH]` out of a `#Source …` header line.
pub(super) fn parse_geometry(header: &str) -> Option<Resolution> {
    let line = header.lines().find(|l| l.starts_with("#Source"))?;
    let open = line.rfind('[')?;
    let close = line.rfind(']')?;
    let (w, h) = line.get(open + 1..close)?.split_once('x')?;
    Some(Resolution::new(w.parse().ok()?, h.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(10_000, 346, 260);
        let mut buf = Vec::new();
        Aedat31.encode(&events, Resolution::DAVIS_346, &mut buf).unwrap();
        let (decoded, res) = Aedat31.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::DAVIS_346);
    }

    #[test]
    fn roundtrip_across_timestamp_overflow() {
        // Events straddling the 2^31 µs boundary must keep exact
        // timestamps via the eventTSOverflow epoch.
        let base = (1u64 << 31) - 2;
        let events: Vec<Event> =
            (0..8).map(|i| Event::on(10, 20, base + i)).collect();
        let mut buf = Vec::new();
        Aedat31.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        let (decoded, _) = Aedat31.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
    }

    #[test]
    fn skips_invalid_events() {
        let events = vec![Event::on(1, 2, 3), Event::off(4, 5, 6)];
        let mut buf = Vec::new();
        Aedat31.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        // Clear the valid bit of the first record (body starts after the
        // ASCII header + 28-byte packet header).
        let body = find(&buf, HEADER_END).unwrap() + HEADER_END.len() + 28;
        buf[body] &= !1;
        let (decoded, _) = Aedat31.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, vec![Event::off(4, 5, 6)]);
    }

    #[test]
    fn rejects_truncation() {
        let events = synthetic_events(100, 64, 64);
        let mut buf = Vec::new();
        Aedat31.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(Aedat31.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn geometry_parsed_from_source_line() {
        assert_eq!(
            parse_geometry("#!AER-DAT3.1\r\n#Source 1: Davis346 [346x260]\r\n"),
            Some(Resolution::DAVIS_346)
        );
        assert_eq!(parse_geometry("#no source"), None);
    }
}
