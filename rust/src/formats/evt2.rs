//! Prophesee EVT 2.0 — 32-bit word stream with TIME_HIGH state.
//!
//! Each word carries a 4-bit type tag in bits 28..32:
//!
//! ```text
//! 0x0 CD_OFF     | type(4) | t_low(6) | x(11) | y(11) |
//! 0x1 CD_ON      | type(4) | t_low(6) | x(11) | y(11) |
//! 0x8 TIME_HIGH  | type(4) | t[33:6] (28 bits)        |
//! 0xA EXT_TRIGGER (skipped on decode)
//! ```
//!
//! A CD word's full timestamp is `(time_high << 6) | t_low` microseconds;
//! the decoder is a small state machine over `time_high`, which is what
//! makes EVT2 interesting for the codec-throughput ablation (state
//! dependence defeats naive vectorization; the hot decode loop lives in
//! [`super::simd`], where the `simd` feature adds a block kernel over
//! runs of CD words between state words).

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::aer::{Event, Resolution};

use super::EventCodec;

pub(super) const TYPE_CD_OFF: u32 = 0x0;
pub(super) const TYPE_CD_ON: u32 = 0x1;
pub(super) const TYPE_TIME_HIGH: u32 = 0x8;
pub(super) const TYPE_EXT_TRIGGER: u32 = 0xA;

/// The codec object.
pub struct Evt2;

impl EventCodec for Evt2 {
    fn name(&self) -> &'static str {
        "evt2"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        write!(
            w,
            "% evt 2.0\n% format EVT2;width={};height={}\n% end\n",
            res.width, res.height
        )?;
        let mut buf: Vec<u8> = Vec::with_capacity(4 * (events.len() + events.len() / 32 + 1));
        // Force a TIME_HIGH before the first CD word.
        let mut time_high: u64 = u64::MAX;
        for ev in events {
            if ev.x >= 2048 || ev.y >= 2048 {
                bail!("evt2: coordinate out of 11-bit range: {ev}");
            }
            let th = ev.t >> 6;
            if th >= 1 << 28 {
                bail!("evt2: timestamp out of 34-bit range: {ev}");
            }
            if th != time_high {
                time_high = th;
                let word = (TYPE_TIME_HIGH << 28) | (th as u32 & 0x0FFF_FFFF);
                buf.extend_from_slice(&word.to_le_bytes());
            }
            let ty = if ev.p.is_on() { TYPE_CD_ON } else { TYPE_CD_OFF };
            let word = (ty << 28)
                | (((ev.t & 0x3F) as u32) << 22)
                | ((ev.x as u32 & 0x7FF) << 11)
                | (ev.y as u32 & 0x7FF);
            buf.extend_from_slice(&word.to_le_bytes());
        }
        w.write_all(&buf)?;
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        let (header, body) = split_percent_header(&bytes);
        let res = parse_geometry(header);
        if body.len() % 4 != 0 {
            bail!("evt2: body length {} not a multiple of 4", body.len());
        }
        let mut events = Vec::with_capacity(body.len() / 4);
        let mut time_high: Option<u64> = None;
        super::simd::decode_evt2_words(body, &mut time_high, &mut events)?;
        let res = res.unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

/// Split `% …` header lines from the binary body. The header ends at the
/// first line that does not start with `%` (or after `% end`).
pub(super) fn split_percent_header(bytes: &[u8]) -> (&[u8], &[u8]) {
    let mut off = 0;
    while off < bytes.len() && bytes[off] == b'%' {
        match bytes[off..].iter().position(|&b| b == b'\n') {
            Some(nl) => off += nl + 1,
            None => {
                off = bytes.len();
                break;
            }
        }
    }
    bytes.split_at(off)
}

/// Parse `width=…;height=…` from header text.
pub(super) fn parse_geometry(header: &[u8]) -> Option<Resolution> {
    let text = std::str::from_utf8(header).ok()?;
    let mut width = None;
    let mut height = None;
    for part in text.split(|c: char| c == ';' || c.is_whitespace()) {
        if let Some(v) = part.strip_prefix("width=") {
            width = v.parse().ok();
        }
        if let Some(v) = part.strip_prefix("height=") {
            height = v.parse().ok();
        }
    }
    Some(Resolution::new(width?, height?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(5000, 1280, 720);
        let mut buf = Vec::new();
        Evt2.encode(&events, Resolution::PROPHESEE_GEN4, &mut buf).unwrap();
        let (decoded, res) = Evt2.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::PROPHESEE_GEN4);
    }

    #[test]
    fn time_high_words_are_amortized() {
        // Events within one 64 µs window share a single TIME_HIGH word.
        let events: Vec<Event> = (0..10).map(|i| Event::on(i, i, 100 + i as u64 % 4)).collect();
        let mut buf = Vec::new();
        Evt2.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        let (header, body) = split_percent_header(&buf);
        assert!(!header.is_empty());
        // 1 TIME_HIGH + 10 CD words.
        assert_eq!(body.len(), 4 * 11);
    }

    #[test]
    fn rejects_cd_before_time_high() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"% evt 2.0\n");
        let cd = (TYPE_CD_ON << 28) | (5 << 22) | (3 << 11) | 4u32;
        buf.extend_from_slice(&cd.to_le_bytes());
        assert!(Evt2.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_out_of_range_coordinates() {
        let events = vec![Event::on(3000, 0, 0)];
        let mut buf = Vec::new();
        assert!(Evt2.encode(&events, Resolution::new(4000, 100), &mut buf).is_err());
    }

    #[test]
    fn skips_trigger_and_unknown_words() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"% evt 2.0\n");
        for word in [
            (TYPE_TIME_HIGH << 28) | 1,
            TYPE_EXT_TRIGGER << 28,
            0x7 << 28, // unknown type
            (TYPE_CD_ON << 28) | (2 << 22) | (9 << 11) | 7,
        ] {
            buf.extend_from_slice(&word.to_le_bytes());
        }
        let (events, _) = Evt2.decode(&mut &buf[..]).unwrap();
        assert_eq!(events, vec![Event::on(9, 7, (1 << 6) | 2)]);
    }
}
