//! AEStream's native packed format: a 16-byte header followed by
//! little-endian packed 64-bit event words ([`crate::aer::packed`]).
//!
//! This is the format the benchmarks cache in RAM — zero parsing state,
//! one `u64` load + bit masks per event, and the decoder is a straight
//! `memcpy`-shaped loop ([`super::simd::decode_raw_words`], unrolled so
//! the compiler vectorizes it).
//!
//! Layout:
//! ```text
//! bytes 0..8   magic  "AERAW1\0\0"
//! bytes 8..10  width  (u16 LE)
//! bytes 10..12 height (u16 LE)
//! bytes 12..16 reserved (zero)
//! bytes 16..   packed events, 8 bytes each (LE)
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::aer::packed;
use crate::aer::{Event, Resolution};

use super::EventCodec;

/// File magic.
pub const MAGIC: &[u8; 8] = b"AERAW1\0\0";

/// The codec object.
pub struct RawPacked;

impl EventCodec for RawPacked {
    fn name(&self) -> &'static str {
        "aeraw"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        let mut header = [0u8; 16];
        header[..8].copy_from_slice(MAGIC);
        header[8..10].copy_from_slice(&res.width.to_le_bytes());
        header[10..12].copy_from_slice(&res.height.to_le_bytes());
        w.write_all(&header)?;
        // Chunked encode: bounded memory for arbitrarily long streams.
        let mut buf = Vec::with_capacity(8 * 4096.min(events.len().max(1)));
        for chunk in events.chunks(4096) {
            buf.clear();
            for ev in chunk {
                buf.extend_from_slice(&packed::pack(ev).to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut header = [0u8; 16];
        r.read_exact(&mut header).context("raw: truncated header")?;
        if &header[..8] != MAGIC {
            bail!("raw: bad magic");
        }
        let width = u16::from_le_bytes([header[8], header[9]]);
        let height = u16::from_le_bytes([header[10], header[11]]);
        let mut body = Vec::new();
        r.read_to_end(&mut body)?;
        if body.len() % 8 != 0 {
            bail!("raw: body length {} not a multiple of 8", body.len());
        }
        let mut events = Vec::with_capacity(body.len() / 8);
        super::simd::decode_raw_words(&body, &mut events);
        Ok((events, Resolution::new(width, height)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(500, 346, 260);
        let mut buf = Vec::new();
        RawPacked.encode(&events, Resolution::DAVIS_346, &mut buf).unwrap();
        assert_eq!(buf.len(), 16 + 8 * 500);
        let (decoded, res) = RawPacked.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::DAVIS_346);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = vec![0u8; 32];
        assert!(RawPacked.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_body() {
        let events = synthetic_events(3, 64, 64);
        let mut buf = Vec::new();
        RawPacked.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        buf.truncate(buf.len() - 3); // chop mid-word
        assert!(RawPacked.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_header() {
        let buf = vec![b'A'; 7];
        assert!(RawPacked.decode(&mut &buf[..]).is_err());
    }
}
