//! Incremental (chunked) decode/encode around the batch codecs.
//!
//! The batch [`EventCodec`](super::EventCodec) API reads a whole stream
//! into memory; the streaming layer ([`crate::stream`]) needs O(chunk)
//! memory instead. [`StreamingDecoder`] accepts arbitrary byte chunks —
//! including chunks that split packed words, packet headers, or CSV
//! lines — carries the partial tail across calls, and emits events as
//! soon as complete records arrive. [`StreamingEncoder`] writes a
//! stream batch-by-batch through the existing codecs: every format's
//! header is a deterministic function of the geometry, so the encoder
//! strips the header from every batch after the first, and the stateful
//! formats (EVT2 `TIME_HIGH`, EVT3 time/row words) simply re-emit their
//! state words at batch boundaries, which decodes identically.
//!
//! Decoder state per format mirrors the batch decoders exactly: EVT2
//! tracks `time_high` across chunks, EVT3 tracks the full
//! (y, time, epoch, vector-base) machine, AEDAT 3.1 waits for complete
//! packets, CSV waits for complete lines.

use std::io::Write;

use anyhow::{bail, Context, Result};

use crate::aer::{Event, Polarity, Resolution};

use super::simd::{self, Evt3State};
use super::{aedat, aedat2, dat, evt2, text, Format};

/// Upper bound on the bytes a header may occupy before the decoder
/// gives up (prevents unbounded buffering on garbage input).
const MAX_HEADER_BYTES: usize = 1 << 20;

/// Upper bound on one AEDAT 3.1 packet's payload. Real encoders cap
/// packets at a few thousand events (ours: 4096 × 8 bytes); anything
/// past this is a corrupt header, which must error rather than buffer.
const MAX_PACKET_BYTES: usize = 1 << 24;

/// Upper bound on one CSV line. Real lines are ~25 bytes; a newline-free
/// stream (binary data misdetected as text) must error rather than
/// buffer the whole input waiting for one.
const MAX_LINE_BYTES: usize = 1 << 20;

/// How a format's body bytes may be cut for parallel decode — the
/// contract between [`StreamingDecoder`] and the shared codec worker
/// plane ([`crate::stream::CodecPlane`]). The variants are ordered by
/// how much concurrency they admit:
///
/// * [`Stateless`](SplitPoints::Stateless): records are independent
///   fixed-width words — any word-aligned cut decodes identically, so
///   one stream's bytes can fan out across workers freely.
/// * [`ScanBoundaries`](SplitPoints::ScanBoundaries): records are
///   fixed-width but carry decoder state; a cheap scan can find words
///   that fully *reset* that state (EVT2 `TIME_HIGH`), and cuts at
///   those words decode independently.
/// * [`Sequential`](SplitPoints::Sequential): the state machine is
///   inherently serial (variable-width records, packet framing, CSV
///   lines) — pieces may still decode *off* the ingest thread, but one
///   piece at a time per stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitPoints {
    /// Any `word`-aligned byte offset is a valid cut.
    Stateless {
        /// Record width in bytes.
        word: usize,
    },
    /// `word`-aligned cuts are valid only at scanned state-reset words.
    ScanBoundaries {
        /// Record width in bytes.
        word: usize,
    },
    /// No intra-stream cuts: decode pieces in order, one at a time.
    Sequential,
}

/// The splittability class of each container format's *body* (headers
/// are always consumed sequentially before any splitting happens).
pub fn split_points(format: Format) -> SplitPoints {
    match format {
        // 8-byte records, no carried state.
        Format::Raw | Format::Aedat2 | Format::Dat => SplitPoints::Stateless { word: 8 },
        // 4-byte words; `TIME_HIGH` resets the only decoder state.
        Format::Evt2 => SplitPoints::ScanBoundaries { word: 4 },
        // EVT3's (y, time, vect-base) machine, AEDAT 3.1 packet
        // framing, and CSV lines are all serial.
        Format::Evt3 | Format::Aedat | Format::Text => SplitPoints::Sequential,
    }
}

/// Per-format body decoding state.
#[derive(Debug)]
enum Body {
    Raw,
    Aedat2,
    Dat,
    Text { lineno: usize },
    Evt2 { time_high: Option<u64> },
    Evt3(Evt3State),
    Aedat31,
}

/// Incremental decoder: feed byte chunks, receive events.
///
/// ```
/// use aestream::formats::{streaming::StreamingDecoder, EventCodec, Format};
/// use aestream::aer::Resolution;
/// let events = aestream::testutil::synthetic_events(100, 64, 64);
/// let mut bytes = Vec::new();
/// Format::Raw.codec().encode(&events, Resolution::new(64, 64), &mut bytes).unwrap();
/// let mut dec = StreamingDecoder::new(Format::Raw);
/// let mut out = Vec::new();
/// for chunk in bytes.chunks(7) { // deliberately splits 8-byte words
///     dec.feed(chunk, &mut out).unwrap();
/// }
/// dec.finish(&mut out).unwrap();
/// assert_eq!(out, events);
/// ```
#[derive(Debug)]
pub struct StreamingDecoder {
    format: Format,
    /// Bytes carried across `feed` calls (undecoded header prefix or a
    /// partial trailing record).
    pending: Vec<u8>,
    header_done: bool,
    res: Option<Resolution>,
    body: Body,
}

impl StreamingDecoder {
    /// Fresh decoder for a known format.
    pub fn new(format: Format) -> Self {
        let body = match format {
            Format::Raw => Body::Raw,
            Format::Aedat2 => Body::Aedat2,
            Format::Dat => Body::Dat,
            Format::Text => Body::Text { lineno: 0 },
            Format::Evt2 => Body::Evt2 { time_high: None },
            Format::Evt3 => Body::Evt3(Evt3State::default()),
            Format::Aedat => Body::Aedat31,
        };
        // Text has no framing header: comment lines are handled inline.
        let header_done = matches!(format, Format::Text);
        StreamingDecoder { format, pending: Vec::new(), header_done, res: None, body }
    }

    /// The format being decoded.
    pub fn format(&self) -> Format {
        self.format
    }

    /// Geometry, once the header has been parsed (formats that do not
    /// record geometry keep returning `None`; callers fall back to a
    /// running bounding box).
    pub fn resolution(&self) -> Option<Resolution> {
        self.res
    }

    /// `true` once the framing header has been fully consumed and
    /// every byte fed from here on is body.
    pub fn header_done(&self) -> bool {
        self.header_done
    }

    /// Header-only feed for split-capable formats: buffer `bytes` and
    /// try to complete the header, returning [`header_done`]
    /// (`Self::header_done`). Once it returns `true`, any body bytes
    /// that arrived with the header tail are waiting in `pending` —
    /// take them with [`take_pending_body`](Self::take_pending_body)
    /// and switch to direct word decoding.
    pub fn feed_header(&mut self, bytes: &[u8]) -> Result<bool> {
        self.pending.extend_from_slice(bytes);
        if !self.header_done {
            if !self.try_header()? && self.pending.len() > MAX_HEADER_BYTES {
                bail!("{}: header exceeds {} bytes", self.format, MAX_HEADER_BYTES);
            }
        }
        Ok(self.header_done)
    }

    /// Take the undecoded body bytes buffered past the header (the tail
    /// of the chunk that completed it). Only meaningful once
    /// [`header_done`](Self::header_done); the decoder keeps running
    /// with an empty carry.
    pub fn take_pending_body(&mut self) -> Vec<u8> {
        debug_assert!(self.header_done, "body bytes exist only after the header");
        std::mem::take(&mut self.pending)
    }

    /// End-of-stream while still inside the header: resolve it the way
    /// [`finish`](Self::finish) would (legal for the comment-header
    /// formats, a truncation error otherwise).
    pub fn finish_header_at_eof(&mut self) -> Result<()> {
        if !self.header_done {
            self.finish_header()?;
        }
        Ok(())
    }

    /// Feed one chunk of bytes, appending decoded events to `out`.
    /// Chunks may split records/packets/lines arbitrarily.
    pub fn feed(&mut self, bytes: &[u8], out: &mut Vec<Event>) -> Result<()> {
        self.pending.extend_from_slice(bytes);
        if !self.header_done {
            if !self.try_header()? {
                if self.pending.len() > MAX_HEADER_BYTES {
                    bail!("{}: header exceeds {} bytes", self.format, MAX_HEADER_BYTES);
                }
                return Ok(());
            }
        }
        self.decode_body(out)
    }

    /// End of stream: flush trailing state and validate completeness
    /// (a partial record or packet is an error, exactly as in the batch
    /// decoders).
    pub fn finish(&mut self, out: &mut Vec<Event>) -> Result<()> {
        if !self.header_done {
            self.finish_header()?;
            if self.header_done {
                self.decode_body(out)?;
            }
        }
        match &mut self.body {
            Body::Raw => {
                if !self.pending.is_empty() {
                    bail!("raw: trailing {} bytes (body not a multiple of 8)", self.pending.len());
                }
            }
            Body::Aedat2 => {
                if !self.pending.is_empty() {
                    bail!(
                        "aedat2: trailing {} bytes (body not a multiple of 8)",
                        self.pending.len()
                    );
                }
            }
            Body::Dat => {
                if !self.pending.is_empty() {
                    bail!("dat: trailing {} bytes (body not a multiple of 8)", self.pending.len());
                }
            }
            Body::Evt2 { .. } => {
                if !self.pending.is_empty() {
                    bail!("evt2: trailing {} bytes (body not a multiple of 4)", self.pending.len());
                }
            }
            Body::Evt3(_) => {
                if !self.pending.is_empty() {
                    bail!("evt3: trailing {} bytes (body not a multiple of 2)", self.pending.len());
                }
            }
            Body::Aedat31 => {
                if !self.pending.is_empty() {
                    bail!("aedat: truncated packet ({} trailing bytes)", self.pending.len());
                }
            }
            Body::Text { lineno } => {
                // The final line may lack a newline, matching the batch
                // decoder's `lines()` behaviour.
                if !self.pending.is_empty() {
                    let line = std::str::from_utf8(&self.pending)
                        .context("text: stream is not valid UTF-8")?
                        .to_owned();
                    text::parse_line(&line, *lineno, &mut self.res, out)?;
                    *lineno += 1;
                    self.pending.clear();
                }
            }
        }
        Ok(())
    }

    /// Try to complete the header from `pending`. Returns `true` once
    /// the header is consumed (body bytes remain in `pending`).
    fn try_header(&mut self) -> Result<bool> {
        match self.format {
            Format::Text => unreachable!("text has no framing header"),
            Format::Raw => {
                if self.pending.len() < 16 {
                    return Ok(false);
                }
                if &self.pending[..8] != super::raw::MAGIC {
                    bail!("raw: bad magic");
                }
                let width = u16::from_le_bytes([self.pending[8], self.pending[9]]);
                let height = u16::from_le_bytes([self.pending[10], self.pending[11]]);
                self.res = Some(Resolution::new(width, height));
                self.pending.drain(..16);
                self.header_done = true;
                Ok(true)
            }
            Format::Aedat => {
                if self.pending.len() >= 12 && !self.pending.starts_with(b"#!AER-DAT3.1") {
                    bail!("aedat: missing #!AER-DAT3.1 signature");
                }
                let Some(pos) = aedat::find(&self.pending, aedat::HEADER_END) else {
                    return Ok(false);
                };
                let end = pos + aedat::HEADER_END.len();
                let header_text = String::from_utf8_lossy(&self.pending[..end]).into_owned();
                self.res = aedat::parse_geometry(&header_text);
                self.pending.drain(..end);
                self.header_done = true;
                Ok(true)
            }
            Format::Aedat2 => {
                if self.pending.len() < 12 {
                    return Ok(false); // signature not yet decidable
                }
                if !self.pending.starts_with(b"#!AER-DAT2.0") {
                    bail!("aedat2: missing #!AER-DAT2.0 signature");
                }
                let Some(end) = scan_comment_header(&self.pending, b'#') else {
                    return Ok(false);
                };
                let header = String::from_utf8_lossy(&self.pending[..end]).into_owned();
                self.res = aedat2::parse_geometry(&header);
                self.pending.drain(..end);
                self.header_done = true;
                Ok(true)
            }
            Format::Evt2 | Format::Evt3 | Format::Dat => {
                let Some(end) = scan_comment_header(&self.pending, b'%') else {
                    return Ok(false);
                };
                let mut consumed = end;
                if self.format == Format::Dat {
                    // Two binary preamble bytes follow the header.
                    if self.pending.len() < end + 2 {
                        return Ok(false);
                    }
                    let (event_type, event_size) = (self.pending[end], self.pending[end + 1]);
                    if event_type != dat::EVENT_TYPE_CD {
                        bail!("dat: unsupported event type {event_type:#x}");
                    }
                    if event_size != dat::EVENT_SIZE {
                        bail!("dat: unsupported event size {event_size}");
                    }
                    consumed = end + 2;
                }
                self.res = evt2::parse_geometry(&self.pending[..end]);
                self.pending.drain(..consumed);
                self.header_done = true;
                Ok(true)
            }
        }
    }

    /// End-of-stream header resolution: either the whole stream was a
    /// header (legal for the `%`-comment formats) or it is an error.
    fn finish_header(&mut self) -> Result<()> {
        match self.format {
            Format::Text => Ok(()),
            Format::Raw => bail!("raw: truncated header"),
            Format::Aedat => {
                if !self.pending.starts_with(b"#!AER-DAT3.1") {
                    bail!("aedat: missing #!AER-DAT3.1 signature");
                }
                bail!("aedat: missing '#End Of ASCII Header'");
            }
            Format::Aedat2 => {
                if !self.pending.starts_with(b"#!AER-DAT2.0") {
                    bail!("aedat2: missing #!AER-DAT2.0 signature");
                }
                // All bytes must be complete '#' lines (⇒ empty body);
                // a dangling line without its newline is an error,
                // exactly as in the batch decoder.
                let mut off = 0usize;
                while off < self.pending.len() && self.pending[off] == b'#' {
                    match self.pending[off..].iter().position(|&b| b == b'\n') {
                        Some(nl) => off += nl + 1,
                        None => bail!("aedat2: unterminated header"),
                    }
                }
                let header = String::from_utf8_lossy(&self.pending[..off]).into_owned();
                self.res = aedat2::parse_geometry(&header);
                self.pending.drain(..off);
                self.header_done = true;
                Ok(())
            }
            Format::Evt2 | Format::Evt3 | Format::Dat => {
                // Mirror `split_percent_header`: an unterminated final
                // `%` line is still header.
                let end = scan_comment_header_permissive(&self.pending, b'%');
                if self.format == Format::Dat {
                    if end == self.pending.len() {
                        bail!("dat: missing binary preamble");
                    }
                    // A lone preamble byte is a truncation error.
                    if self.pending.len() < end + 2 {
                        bail!("dat: missing binary preamble");
                    }
                }
                self.res = evt2::parse_geometry(&self.pending[..end]);
                let body_start = if self.format == Format::Dat {
                    let (event_type, event_size) = (self.pending[end], self.pending[end + 1]);
                    if event_type != dat::EVENT_TYPE_CD {
                        bail!("dat: unsupported event type {event_type:#x}");
                    }
                    if event_size != dat::EVENT_SIZE {
                        bail!("dat: unsupported event size {event_size}");
                    }
                    end + 2
                } else {
                    end
                };
                self.pending.drain(..body_start);
                self.header_done = true;
                Ok(())
            }
        }
    }

    /// Decode every complete record in `pending`, retaining the partial
    /// tail for the next `feed`.
    fn decode_body(&mut self, out: &mut Vec<Event>) -> Result<()> {
        match &mut self.body {
            Body::Raw => {
                let n = self.pending.len() / 8 * 8;
                simd::decode_raw_words(&self.pending[..n], out);
                self.pending.drain(..n);
                Ok(())
            }
            Body::Aedat2 => {
                let n = self.pending.len() / 8 * 8;
                simd::decode_aedat2_words(&self.pending[..n], out);
                self.pending.drain(..n);
                Ok(())
            }
            Body::Dat => {
                let n = self.pending.len() / 8 * 8;
                simd::decode_dat_words(&self.pending[..n], out);
                self.pending.drain(..n);
                Ok(())
            }
            Body::Evt2 { time_high } => {
                let n = self.pending.len() / 4 * 4;
                simd::decode_evt2_words(&self.pending[..n], time_high, out)?;
                self.pending.drain(..n);
                Ok(())
            }
            Body::Evt3(st) => {
                let n = self.pending.len() / 2 * 2;
                simd::decode_evt3_words(&self.pending[..n], st, out)?;
                self.pending.drain(..n);
                Ok(())
            }
            Body::Aedat31 => {
                let mut off = 0usize;
                loop {
                    if self.pending.len() - off < 28 {
                        break;
                    }
                    let h = &self.pending[off..off + 28];
                    let event_type = i16::from_le_bytes([h[0], h[1]]);
                    let event_size = i32::from_le_bytes(h[4..8].try_into().unwrap());
                    let ts_overflow = i32::from_le_bytes(h[12..16].try_into().unwrap()) as u64;
                    let event_number = i32::from_le_bytes(h[20..24].try_into().unwrap());
                    if event_size <= 0 || event_number < 0 {
                        bail!("aedat: corrupt packet header (size {event_size}, n {event_number})");
                    }
                    let payload = event_size as usize * event_number as usize;
                    // A streaming decoder cannot compare against the
                    // remaining file length (the batch decoder's
                    // truncation check), so an implausible payload must
                    // be rejected outright — otherwise a corrupt header
                    // would make `pending` buffer the entire rest of the
                    // input, defeating the O(chunk) guarantee.
                    if payload > MAX_PACKET_BYTES {
                        bail!("aedat: implausible packet payload of {payload} bytes");
                    }
                    if self.pending.len() - off < 28 + payload {
                        break; // wait for the rest of this packet
                    }
                    let body = &self.pending[off + 28..off + 28 + payload];
                    if event_type == aedat::POLARITY_EVENT && event_size == aedat::EVENT_SIZE {
                        for rec in body.chunks_exact(8) {
                            let data = u32::from_le_bytes(rec[0..4].try_into().unwrap());
                            if data & 1 == 0 {
                                continue; // invalidated event
                            }
                            let ts = u32::from_le_bytes(rec[4..8].try_into().unwrap()) as u64;
                            out.push(Event {
                                x: ((data >> 17) & 0x7FFF) as u16,
                                y: ((data >> 2) & 0x7FFF) as u16,
                                p: Polarity::from_bool(data & 2 != 0),
                                t: (ts_overflow << 31) | ts,
                            });
                        }
                    }
                    // Unknown event types are skipped (spec: readers must ignore).
                    off += 28 + payload;
                }
                self.pending.drain(..off);
                Ok(())
            }
            Body::Text { lineno } => {
                let Some(last_nl) = self.pending.iter().rposition(|&b| b == b'\n') else {
                    if self.pending.len() > MAX_LINE_BYTES {
                        bail!("text: line exceeds {} bytes", MAX_LINE_BYTES);
                    }
                    return Ok(()); // no complete line yet
                };
                let complete = std::str::from_utf8(&self.pending[..=last_nl])
                    .context("text: stream is not valid UTF-8")?
                    .to_owned();
                for line in complete.lines() {
                    text::parse_line(line, *lineno, &mut self.res, out)?;
                    *lineno += 1;
                }
                self.pending.drain(..=last_nl);
                Ok(())
            }
        }
    }
}

/// Scan comment-prefixed header lines. Returns the body offset once a
/// line starting with something other than `marker` is seen; `None`
/// while the header may still be growing (mid-line, or the buffer ends
/// exactly at a line boundary).
fn scan_comment_header(bytes: &[u8], marker: u8) -> Option<usize> {
    let mut off = 0;
    while off < bytes.len() && bytes[off] == marker {
        match bytes[off..].iter().position(|&b| b == b'\n') {
            Some(nl) => off += nl + 1,
            None => return None,
        }
    }
    if off < bytes.len() {
        Some(off)
    } else {
        None
    }
}

/// End-of-stream variant: an unterminated final comment line (or a
/// buffer that is all header) counts as header, mirroring the batch
/// `split_percent_header`.
fn scan_comment_header_permissive(bytes: &[u8], marker: u8) -> usize {
    let mut off = 0;
    while off < bytes.len() && bytes[off] == marker {
        match bytes[off..].iter().position(|&b| b == b'\n') {
            Some(nl) => off += nl + 1,
            None => return bytes.len(),
        }
    }
    off
}

/// Incremental encoder: write a stream batch-by-batch in any format.
///
/// Each batch is encoded through the batch codec; the deterministic
/// header (exactly the bytes `encode(&[], res)` produces) is stripped
/// from every batch after the first. Stateful formats re-emit their
/// state words (EVT2 `TIME_HIGH`, EVT3 time/row words, AEDAT 3.1 packet
/// headers) at batch boundaries — byte output can differ from a
/// single-shot encode, but decodes to the identical event stream.
pub struct StreamingEncoder {
    format: Format,
    res: Resolution,
    header_len: usize,
    started: bool,
    scratch: Vec<u8>,
}

impl StreamingEncoder {
    /// New encoder for a sensor of geometry `res`.
    pub fn new(format: Format, res: Resolution) -> Result<Self> {
        let mut empty = Vec::new();
        format.codec().encode(&[], res, &mut empty)?;
        Ok(StreamingEncoder {
            format,
            res,
            header_len: empty.len(),
            started: false,
            scratch: Vec::new(),
        })
    }

    /// The target format.
    pub fn format(&self) -> Format {
        self.format
    }

    /// Encode one batch (timestamps must continue the stream's
    /// non-decreasing order across batches).
    pub fn write_batch(&mut self, events: &[Event], w: &mut dyn Write) -> Result<()> {
        if events.is_empty() && self.started {
            return Ok(());
        }
        self.scratch.clear();
        self.format.codec().encode(events, self.res, &mut self.scratch)?;
        let skip = if self.started { self.header_len } else { 0 };
        w.write_all(&self.scratch[skip..])?;
        self.started = true;
        Ok(())
    }

    /// Finish the stream: ensures the header exists even for an empty
    /// stream (so zero-event files stay readable).
    pub fn finish(&mut self, w: &mut dyn Write) -> Result<()> {
        if !self.started {
            self.write_batch(&[], w)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::EventCodec;
    use super::*;
    use crate::testutil::{synthetic_events, synthetic_events_seeded};

    /// Decode `bytes` through the streaming decoder in fixed-size
    /// chunks, returning events and the final geometry.
    fn chunked_decode(
        format: Format,
        bytes: &[u8],
        chunk: usize,
    ) -> (Vec<Event>, Option<Resolution>) {
        let mut dec = StreamingDecoder::new(format);
        let mut out = Vec::new();
        for piece in bytes.chunks(chunk.max(1)) {
            dec.feed(piece, &mut out).unwrap_or_else(|e| panic!("{format}: feed: {e}"));
        }
        dec.finish(&mut out).unwrap_or_else(|e| panic!("{format}: finish: {e}"));
        (out, dec.resolution())
    }

    #[test]
    fn chunked_decode_matches_batch_for_all_formats_and_chunk_sizes() {
        let events = synthetic_events(3000, 346, 260);
        let res = Resolution::DAVIS_346;
        for format in Format::ALL {
            let codec = format.codec();
            let mut bytes = Vec::new();
            codec.encode(&events, res, &mut bytes).unwrap();
            // 1 and 3 split every multi-byte word; 7 misaligns 8-byte
            // records; 64 splits AEDAT packets mid-payload.
            for chunk in [1usize, 3, 7, 64, 4096] {
                let (decoded, dres) = chunked_decode(format, &bytes, chunk);
                assert_eq!(decoded, events, "{format} chunk={chunk}");
                assert_eq!(dres, Some(res), "{format} chunk={chunk} geometry");
            }
        }
    }

    #[test]
    fn chunked_encode_decodes_identically_for_all_formats() {
        let events = synthetic_events_seeded(2500, 640, 480, 0xBEEF);
        let res = Resolution::new(640, 480);
        for format in Format::ALL {
            let mut enc = StreamingEncoder::new(format, res).unwrap();
            let mut bytes = Vec::new();
            for batch in events.chunks(317) {
                enc.write_batch(batch, &mut bytes).unwrap();
            }
            enc.finish(&mut bytes).unwrap();
            let (decoded, dres) =
                format.codec().decode(&mut &bytes[..]).unwrap_or_else(|e| panic!("{format}: {e}"));
            assert_eq!(decoded, events, "{format}");
            assert_eq!(dres, res, "{format}");
        }
    }

    #[test]
    fn empty_stream_roundtrips_through_streaming_pair() {
        let res = Resolution::new(64, 64);
        for format in Format::ALL {
            let mut enc = StreamingEncoder::new(format, res).unwrap();
            let mut bytes = Vec::new();
            enc.finish(&mut bytes).unwrap();
            let (decoded, _) = chunked_decode(format, &bytes, 5);
            assert!(decoded.is_empty(), "{format} produced phantom events");
        }
    }

    #[test]
    fn evt3_rollover_survives_chunk_boundaries() {
        let base = (1u64 << 24) - 3;
        let events: Vec<Event> = (0..6).map(|i| Event::off(5, 6, base + i)).collect();
        let mut bytes = Vec::new();
        Format::Evt3.codec().encode(&events, Resolution::new(64, 64), &mut bytes).unwrap();
        let (decoded, _) = chunked_decode(Format::Evt3, &bytes, 1);
        assert_eq!(decoded, events);
    }

    #[test]
    fn truncated_tail_is_an_error_not_a_panic() {
        let events = synthetic_events(50, 64, 64);
        let res = Resolution::new(64, 64);
        for format in Format::ALL {
            if format == Format::Text {
                continue; // text tolerates a missing trailing newline
            }
            let mut bytes = Vec::new();
            format.codec().encode(&events, res, &mut bytes).unwrap();
            bytes.truncate(bytes.len() - 1);
            let mut dec = StreamingDecoder::new(format);
            let mut out = Vec::new();
            let fed = dec.feed(&bytes, &mut out);
            let result = fed.and_then(|_| dec.finish(&mut out));
            assert!(result.is_err(), "{format} accepted a truncated stream");
        }
    }

    #[test]
    fn header_feed_path_hands_over_exact_body_bytes() {
        // The codec plane's front end consumes the header through
        // `feed_header`/`take_pending_body`; the handover must be
        // byte-exact for every format, at adversarial chunk sizes.
        let events = synthetic_events(200, 64, 64);
        let res = Resolution::new(64, 64);
        for format in Format::ALL {
            let mut bytes = Vec::new();
            format.codec().encode(&events, res, &mut bytes).unwrap();
            for chunk in [1usize, 3, 16, 97] {
                let mut dec = StreamingDecoder::new(format);
                let mut body = Vec::new();
                let mut fed = 0usize;
                for piece in bytes.chunks(chunk) {
                    if !dec.header_done() {
                        fed += piece.len();
                        if dec.feed_header(piece).unwrap() {
                            body.extend_from_slice(&dec.take_pending_body());
                        }
                    } else {
                        body.extend_from_slice(piece);
                        fed += piece.len();
                    }
                }
                assert!(dec.header_done(), "{format} chunk={chunk}: header never completed");
                assert_eq!(fed, bytes.len());
                // Decoding the handed-over body through a *fresh* body
                // decode must reproduce the inline result.
                let mut inline = StreamingDecoder::new(format);
                let mut expect = Vec::new();
                inline.feed(&bytes, &mut expect).unwrap();
                inline.finish(&mut expect).unwrap();
                let mut out = Vec::new();
                dec.feed(&body, &mut out).unwrap();
                dec.finish(&mut out).unwrap();
                assert_eq!(out, expect, "{format} chunk={chunk}");
            }
        }
    }

    #[test]
    fn split_points_classify_every_format() {
        use SplitPoints::*;
        for format in Format::ALL {
            let class = split_points(format);
            match format {
                Format::Raw | Format::Aedat2 | Format::Dat => {
                    assert_eq!(class, Stateless { word: 8 }, "{format}")
                }
                Format::Evt2 => assert_eq!(class, ScanBoundaries { word: 4 }),
                Format::Evt3 | Format::Aedat | Format::Text => {
                    assert_eq!(class, Sequential, "{format}")
                }
            }
        }
    }

    #[test]
    fn streaming_decoder_rejects_bad_magic_early() {
        let mut dec = StreamingDecoder::new(Format::Raw);
        let mut out = Vec::new();
        assert!(dec.feed(&[0u8; 32], &mut out).is_err());
    }

    #[test]
    fn aedat_implausible_packet_payload_errors_instead_of_buffering() {
        // A corrupt packet header claiming a multi-GiB payload must
        // error immediately, not buffer the rest of the stream.
        let events = synthetic_events(4, 64, 64);
        let mut bytes = Vec::new();
        Format::Aedat.codec().encode(&events, Resolution::new(64, 64), &mut bytes).unwrap();
        let body = super::aedat::find(&bytes, super::aedat::HEADER_END).unwrap()
            + super::aedat::HEADER_END.len();
        // Overwrite eventNumber (bytes 20..24 of the packet header).
        bytes[body + 20..body + 24].copy_from_slice(&i32::MAX.to_le_bytes());
        let mut dec = StreamingDecoder::new(Format::Aedat);
        let mut out = Vec::new();
        let err = dec.feed(&bytes, &mut out).unwrap_err();
        assert!(format!("{err}").contains("implausible"), "{err}");
    }
}
