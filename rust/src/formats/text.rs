//! Human-readable CSV event format: one `x,y,p,t` line per event.
//!
//! Matches what `aestream output stdout` prints (Fig. 2B of the paper
//! pipes events to standard output) so shell pipelines can round-trip.
//! Header lines start with `#`; geometry is recorded as
//! `# resolution WxH`.

use std::io::{BufRead, BufReader, Read, Write};

use anyhow::{bail, Context, Result};

use crate::aer::{Event, Polarity, Resolution};

use super::EventCodec;

/// The codec object.
pub struct TextCsv;

impl EventCodec for TextCsv {
    fn name(&self) -> &'static str {
        "csv"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        // Buffer lines manually; going through `writeln!` per event costs
        // a formatter setup each time and this encoder doubles as the
        // stdout sink on the hot path.
        let mut out = String::with_capacity(24 * events.len().min(4096) + 64);
        out.push_str(&format!("# aestream csv\n# resolution {}x{}\n", res.width, res.height));
        for (i, ev) in events.iter().enumerate() {
            use std::fmt::Write as _;
            writeln!(out, "{},{},{},{}", ev.x, ev.y, u8::from(ev.p.is_on()), ev.t).unwrap();
            if i % 4096 == 4095 {
                w.write_all(out.as_bytes())?;
                out.clear();
            }
        }
        w.write_all(out.as_bytes())?;
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let reader = BufReader::new(r);
        let mut events = Vec::new();
        let mut res: Option<Resolution> = None;
        for (lineno, line) in reader.lines().enumerate() {
            let line = line?;
            parse_line(&line, lineno, &mut res, &mut events)?;
        }
        let res = res.unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

/// Parse one CSV line, appending to `events` (or updating `res` for a
/// `# resolution WxH` comment). Shared by the batch decoder above and
/// the chunked [`super::streaming`] decoder; `lineno` is 0-based and
/// only used for error messages.
pub(super) fn parse_line(
    line: &str,
    lineno: usize,
    res: &mut Option<Resolution>,
    events: &mut Vec<Event>,
) -> Result<()> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(());
    }
    if let Some(rest) = line.strip_prefix('#') {
        let rest = rest.trim();
        if let Some(geom) = rest.strip_prefix("resolution ") {
            let (w, h) = geom
                .split_once('x')
                .with_context(|| format!("line {}: bad resolution", lineno + 1))?;
            *res = Some(Resolution::new(w.trim().parse()?, h.trim().parse()?));
        }
        return Ok(());
    }
    let mut parts = line.split(',');
    let (x, y, p, t) = (
        parts.next().with_context(|| format!("line {}: missing x", lineno + 1))?,
        parts.next().with_context(|| format!("line {}: missing y", lineno + 1))?,
        parts.next().with_context(|| format!("line {}: missing p", lineno + 1))?,
        parts.next().with_context(|| format!("line {}: missing t", lineno + 1))?,
    );
    if parts.next().is_some() {
        bail!("line {}: too many fields", lineno + 1);
    }
    events.push(Event {
        x: x.trim().parse().with_context(|| format!("line {}: x", lineno + 1))?,
        y: y.trim().parse().with_context(|| format!("line {}: y", lineno + 1))?,
        p: Polarity::from_bool(match p.trim() {
            "0" | "false" => false,
            "1" | "true" => true,
            other => bail!("line {}: bad polarity {other:?}", lineno + 1),
        }),
        t: t.trim().parse().with_context(|| format!("line {}: t", lineno + 1))?,
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(300, 128, 128);
        let mut buf = Vec::new();
        TextCsv.encode(&events, Resolution::DVS_128, &mut buf).unwrap();
        let (decoded, res) = TextCsv.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::DVS_128);
    }

    #[test]
    fn parses_hand_written_variants() {
        let src = "# comment\n\n1, 2, true, 100\n3,4,0,200\n";
        let (events, res) = TextCsv.decode(&mut src.as_bytes()).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0], Event::on(1, 2, 100));
        assert_eq!(events[1], Event::off(3, 4, 200));
        // No geometry header: inferred bounding box.
        assert_eq!((res.width, res.height), (4, 5));
    }

    #[test]
    fn rejects_garbage_polarity() {
        assert!(TextCsv.decode(&mut "1,2,maybe,3\n".as_bytes()).is_err());
    }

    #[test]
    fn rejects_extra_fields() {
        assert!(TextCsv.decode(&mut "1,2,1,3,9\n".as_bytes()).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(TextCsv.decode(&mut "1,2,1\n".as_bytes()).is_err());
    }
}
