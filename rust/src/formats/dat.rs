//! Prophesee DAT — fixed 8-byte records, the simplest vendor format.
//!
//! An ASCII `% …` header, then two bytes (event type `0x0C` = 2D CD
//! event, event size `8`), then records of
//!
//! ```text
//! u32 timestamp (µs, little-endian)
//! u32 data: x(14) | y(14) | p(4)    (x in bits 0..14, y 14..28, p 28..32)
//! ```
//!
//! 32-bit timestamps cap a recording at ~71.6 minutes; like the vendor
//! tooling we reject longer streams at encode time rather than silently
//! wrapping.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::aer::{Event, Polarity, Resolution};

use super::evt2::{parse_geometry, split_percent_header};
use super::EventCodec;

pub(super) const EVENT_TYPE_CD: u8 = 0x0C;
pub(super) const EVENT_SIZE: u8 = 8;

/// The codec object.
pub struct Dat;

impl EventCodec for Dat {
    fn name(&self) -> &'static str {
        "dat"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        write!(
            w,
            "% DAT v2\n% format DAT;width={};height={}\n% end\n",
            res.width, res.height
        )?;
        w.write_all(&[EVENT_TYPE_CD, EVENT_SIZE])?;
        let mut buf = Vec::with_capacity(8 * events.len());
        for ev in events {
            if ev.t > u32::MAX as u64 {
                bail!("dat: timestamp {} exceeds 32 bits", ev.t);
            }
            if ev.x >= 1 << 14 || ev.y >= 1 << 14 {
                bail!("dat: coordinate out of 14-bit range: {ev}");
            }
            let data: u32 = (ev.x as u32)
                | ((ev.y as u32) << 14)
                | (u32::from(ev.p.is_on()) << 28);
            buf.extend_from_slice(&(ev.t as u32).to_le_bytes());
            buf.extend_from_slice(&data.to_le_bytes());
        }
        w.write_all(&buf)?;
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        let (header, body) = split_percent_header(&bytes);
        let res = parse_geometry(header);
        if body.len() < 2 {
            bail!("dat: missing binary preamble");
        }
        let (event_type, event_size) = (body[0], body[1]);
        if event_type != EVENT_TYPE_CD {
            bail!("dat: unsupported event type {event_type:#x}");
        }
        if event_size != EVENT_SIZE {
            bail!("dat: unsupported event size {event_size}");
        }
        let body = &body[2..];
        if body.len() % 8 != 0 {
            bail!("dat: body length {} not a multiple of 8", body.len());
        }
        let mut events = Vec::with_capacity(body.len() / 8);
        for rec in body.chunks_exact(8) {
            let t = u32::from_le_bytes(rec[0..4].try_into().unwrap()) as u64;
            let data = u32::from_le_bytes(rec[4..8].try_into().unwrap());
            events.push(Event {
                t,
                x: (data & 0x3FFF) as u16,
                y: ((data >> 14) & 0x3FFF) as u16,
                p: Polarity::from_bool((data >> 28) & 0xF != 0),
            });
        }
        let res = res.unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip() {
        let events = synthetic_events(3000, 1280, 720);
        let mut buf = Vec::new();
        Dat.encode(&events, Resolution::PROPHESEE_GEN4, &mut buf).unwrap();
        let (decoded, res) = Dat.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!(res, Resolution::PROPHESEE_GEN4);
    }

    #[test]
    fn rejects_over_32bit_timestamps() {
        let events = vec![Event::on(0, 0, 1 << 33)];
        let mut buf = Vec::new();
        assert!(Dat.encode(&events, Resolution::new(4, 4), &mut buf).is_err());
    }

    #[test]
    fn rejects_wrong_event_type() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"% DAT v2\n");
        buf.extend_from_slice(&[0x01, 8]);
        assert!(Dat.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_record() {
        let events = synthetic_events(4, 64, 64);
        let mut buf = Vec::new();
        Dat.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        buf.truncate(buf.len() - 1);
        assert!(Dat.decode(&mut &buf[..]).is_err());
    }
}
