//! Prophesee EVT 3.0 — 16-bit word stream with vectorized runs.
//!
//! The densest of the Prophesee formats: a stateful decoder tracks the
//! current `y` row, time base, and an x base for *vector* words that emit
//! up to 12 events from a single 16-bit mask. Word types (bits 12..16):
//!
//! ```text
//! 0x0 EVT_ADDR_Y   | y(11)            | orig(1) |
//! 0x2 EVT_ADDR_X   | x(11)            | pol(1)  |   single event
//! 0x3 VECT_BASE_X  | x(11)            | pol(1)  |   set vector base
//! 0x4 VECT_12      | valid mask (12)  |             12-pixel run @ base
//! 0x5 VECT_8       | valid mask (8)   |             8-pixel run @ base
//! 0x6 EVT_TIME_LOW | t[11:0]          |
//! 0x8 EVT_TIME_HIGH| t[23:12]         |
//! ```
//!
//! Time is 24-bit with rollover; the decoder widens it to 64-bit by
//! tracking wraps (TIME_HIGH decreasing ⇒ +2^24). The decode state
//! machine itself lives in [`super::simd`] (shared with the streaming
//! decoder, with an SSE2 path over `ADDR_X` runs). The encoder uses
//! VECT_12 whenever ≥2 same-polarity events share a row and 12-pixel
//! window at one timestamp, which is what event cameras actually emit on
//! edges — and why EVT3 beats EVT2 on wire size for structured scenes.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::aer::{Event, Resolution};

use super::evt2::{parse_geometry, split_percent_header};
use super::EventCodec;

pub(super) const TY_ADDR_Y: u16 = 0x0;
pub(super) const TY_ADDR_X: u16 = 0x2;
pub(super) const TY_VECT_BASE_X: u16 = 0x3;
pub(super) const TY_VECT_12: u16 = 0x4;
pub(super) const TY_VECT_8: u16 = 0x5;
pub(super) const TY_TIME_LOW: u16 = 0x6;
pub(super) const TY_TIME_HIGH: u16 = 0x8;

/// The codec object.
pub struct Evt3;

impl EventCodec for Evt3 {
    fn name(&self) -> &'static str {
        "evt3"
    }

    fn encode(&self, events: &[Event], res: Resolution, w: &mut dyn Write) -> Result<()> {
        write!(
            w,
            "% evt 3.0\n% format EVT3;width={};height={}\n% end\n",
            res.width, res.height
        )?;
        let mut out: Vec<u8> = Vec::with_capacity(2 * events.len());
        let mut word = |ty: u16, payload: u16| {
            out.extend_from_slice(&((ty << 12) | (payload & 0x0FFF)).to_le_bytes());
        };

        let mut cur_t: Option<u64> = None;
        let mut cur_y: Option<u16> = None;
        let mut i = 0usize;
        while i < events.len() {
            let ev = &events[i];
            if ev.x >= 2048 || ev.y >= 2048 {
                bail!("evt3: coordinate out of 11-bit range: {ev}");
            }
            // --- time state
            if cur_t != Some(ev.t) {
                let high = ((ev.t >> 12) & 0xFFF) as u16;
                let low = (ev.t & 0xFFF) as u16;
                let need_high =
                    cur_t.map_or(true, |p| (p >> 12) != (ev.t >> 12));
                if need_high {
                    word(TY_TIME_HIGH, high);
                }
                word(TY_TIME_LOW, low);
                cur_t = Some(ev.t);
            }
            // --- row state
            if cur_y != Some(ev.y) {
                word(TY_ADDR_Y, ev.y & 0x7FF);
                cur_y = Some(ev.y);
            }
            // --- vector run detection: same t, same y, same polarity,
            //     strictly increasing x within a 12-pixel window.
            let mut run_end = i + 1;
            while run_end < events.len() {
                let nx = &events[run_end];
                if nx.t != ev.t || nx.y != ev.y || nx.p != ev.p {
                    break;
                }
                if nx.x <= events[run_end - 1].x || nx.x - ev.x >= 12 {
                    break;
                }
                run_end += 1;
            }
            if run_end - i >= 2 {
                let mut mask: u16 = 0;
                for e in &events[i..run_end] {
                    mask |= 1 << (e.x - ev.x);
                }
                word(TY_VECT_BASE_X, (ev.x & 0x7FF) | (u16::from(ev.p.is_on()) << 11));
                word(TY_VECT_12, mask);
                i = run_end;
            } else {
                word(TY_ADDR_X, (ev.x & 0x7FF) | (u16::from(ev.p.is_on()) << 11));
                i += 1;
            }
        }
        w.write_all(&out)?;
        Ok(())
    }

    fn decode(&self, r: &mut dyn Read) -> Result<(Vec<Event>, Resolution)> {
        let mut bytes = Vec::new();
        r.read_to_end(&mut bytes)?;
        let (header, body) = split_percent_header(&bytes);
        let res = parse_geometry(header);
        if body.len() % 2 != 0 {
            bail!("evt3: body length {} not a multiple of 2", body.len());
        }

        let mut events = Vec::with_capacity(body.len() / 2);
        let mut state = super::simd::Evt3State::default();
        super::simd::decode_evt3_words(body, &mut state, &mut events)?;
        let res = res.unwrap_or_else(|| super::bounding_resolution(&events));
        Ok((events, res))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn roundtrip_random() {
        let events = synthetic_events(5000, 640, 480);
        let mut buf = Vec::new();
        Evt3.encode(&events, Resolution::new(640, 480), &mut buf).unwrap();
        let (decoded, res) = Evt3.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
        assert_eq!((res.width, res.height), (640, 480));
    }

    #[test]
    fn roundtrip_edge_like_runs_compress() {
        // A vertical edge: consecutive x at the same (t, y, p) — the shape
        // VECT_12 exists for. Verify both correctness and compression.
        let mut events = Vec::new();
        for t in 0..50u64 {
            for x in 0..10u16 {
                events.push(Event::on(100 + x, 37, t * 100));
            }
        }
        let mut buf3 = Vec::new();
        Evt3.encode(&events, Resolution::new(640, 480), &mut buf3).unwrap();
        let (decoded, _) = Evt3.decode(&mut &buf3[..]).unwrap();
        assert_eq!(decoded, events);

        let mut buf2 = Vec::new();
        super::super::evt2::Evt2.encode(&events, Resolution::new(640, 480), &mut buf2).unwrap();
        assert!(
            buf3.len() < buf2.len(),
            "EVT3 ({}) should out-compress EVT2 ({}) on runs",
            buf3.len(),
            buf2.len()
        );
    }

    #[test]
    fn roundtrip_across_24bit_rollover() {
        let base = (1u64 << 24) - 3;
        let events: Vec<Event> = (0..6).map(|i| Event::off(5, 6, base + i)).collect();
        let mut buf = Vec::new();
        Evt3.encode(&events, Resolution::new(64, 64), &mut buf).unwrap();
        let (decoded, _) = Evt3.decode(&mut &buf[..]).unwrap();
        assert_eq!(decoded, events);
    }

    #[test]
    fn rejects_event_before_time() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"% evt 3.0\n");
        buf.extend_from_slice(&((TY_ADDR_X << 12) | 5).to_le_bytes());
        assert!(Evt3.decode(&mut &buf[..]).is_err());
    }

    #[test]
    fn odd_body_length_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"% evt 3.0\n");
        buf.push(0xAB);
        assert!(Evt3.decode(&mut &buf[..]).is_err());
    }
}
