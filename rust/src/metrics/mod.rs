//! Lightweight metrics: counters, rate meters, and timing histograms.
//!
//! The coordinator and benches report throughput (events/s, frames/s)
//! and latency distributions; everything here is allocation-free on the
//! hot path and has no dependencies.

use std::time::{Duration, Instant};

/// Per-node counters for one source or sink of a stream topology.
///
/// [`crate::stream::StreamReport`] carries one of these per topology
/// node, so fan-in/fan-out runs can attribute traffic (and stalls) to
/// individual sensors and outputs instead of reporting only edge-level
/// aggregates.
#[derive(Debug, Clone, Default)]
pub struct NodeReport {
    /// Human-readable node description (the node's `describe()`).
    pub name: String,
    /// Events through this node (sources: pulled; sinks: routed in).
    pub events: u64,
    /// Non-empty batches through this node.
    pub batches: u64,
    /// Times a writer found this node's queue full and suspended
    /// (source pump threads / the fan-out router).
    pub backpressure_waits: u64,
    /// Events the node itself discarded (e.g. outside a source's
    /// claimed geometry, or filtered by a pipeline stage; 0 elsewhere).
    pub dropped: u64,
    /// Frames produced (frame-binning sinks; 0 elsewhere).
    pub frames: u64,
    /// Sharded stage nodes: home events routed to each shard (ghost
    /// copies excluded). Empty for unsharded nodes. Sums to
    /// [`events`](NodeReport::events).
    pub shard_events: Vec<u64>,
}

impl NodeReport {
    /// Load imbalance across shards: the busiest shard's event count
    /// over the mean (1.0 = perfectly balanced; 0.0 when the node is
    /// unsharded or saw no events). A skew of N on N shards means one
    /// stripe did all the work — the signal to re-cut stripes or drop
    /// the shard count.
    pub fn shard_skew(&self) -> f64 {
        if self.shard_events.is_empty() {
            return 0.0;
        }
        let total: u64 = self.shard_events.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / self.shard_events.len() as f64;
        *self.shard_events.iter().max().expect("nonempty") as f64 / mean
    }
}

/// Wall-clock stopwatch with µs readout.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed microseconds.
    pub fn elapsed_us(&self) -> u64 {
        self.elapsed().as_micros() as u64
    }
}

/// Throughput meter: items per second over the measured span.
#[derive(Debug, Default, Clone)]
pub struct RateMeter {
    items: u64,
    span: Duration,
}

impl RateMeter {
    /// Fresh meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` items processed over `span`.
    pub fn record(&mut self, n: u64, span: Duration) {
        self.items += n;
        self.span += span;
    }

    /// Total items recorded.
    pub fn items(&self) -> u64 {
        self.items
    }

    /// Items per second (0 if nothing recorded).
    pub fn rate(&self) -> f64 {
        if self.span.is_zero() {
            0.0
        } else {
            self.items as f64 / self.span.as_secs_f64()
        }
    }
}

/// Fixed-bucket log-scale duration histogram: 1 µs … ~17 s in 25
/// power-of-two buckets, constant memory, O(1) record.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// buckets[i] counts samples in [2^i, 2^(i+1)) µs.
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 25], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    /// Fresh histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(24);
        self.buckets[bucket] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean in µs.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Maximum recorded µs.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile (bucket upper bound), `q` in [0,1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1 << (i + 1);
            }
        }
        1 << 25
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_meter_computes_rate() {
        let mut m = RateMeter::new();
        m.record(1000, Duration::from_millis(500));
        m.record(1000, Duration::from_millis(500));
        assert_eq!(m.items(), 2000);
        assert!((m.rate() - 2000.0).abs() < 1.0);
    }

    #[test]
    fn empty_meter_rate_is_zero() {
        assert_eq!(RateMeter::new().rate(), 0.0);
    }

    #[test]
    fn histogram_buckets_and_stats() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 1000, 1000, 1_000_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max_us(), 1_000_000);
        assert!(h.mean_us() > 0.0);
        // Median: 3rd of 6 ordered samples is 4 µs → bucket bound 8 µs.
        let p50 = h.quantile_us(0.5);
        assert!((4..=8).contains(&p50), "p50 = {p50}");
        // p100 covers the max.
        assert!(h.quantile_us(1.0) >= 1_000_000);
    }

    #[test]
    fn shard_skew_measures_imbalance() {
        let mut node = NodeReport::default();
        assert_eq!(node.shard_skew(), 0.0, "unsharded node has no skew");
        node.shard_events = vec![100, 100, 100, 100];
        assert!((node.shard_skew() - 1.0).abs() < 1e-9, "balanced = 1.0");
        node.shard_events = vec![400, 0, 0, 0];
        assert!((node.shard_skew() - 4.0).abs() < 1e-9, "one hot stripe = N");
        node.shard_events = vec![0, 0];
        assert_eq!(node.shard_skew(), 0.0, "no traffic, no skew");
    }

    #[test]
    fn stopwatch_monotonic() {
        let s = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(s.elapsed_us() >= 1000);
    }
}
