//! Lightweight metrics: counters, rate meters, timing histograms, and
//! the live telemetry plane.
//!
//! The coordinator and benches report throughput (events/s, frames/s)
//! and latency distributions; everything here is allocation-free on the
//! hot path and has no dependencies.
//!
//! [`LiveNode`] is the live half: per-node counters as shared atomic
//! cells that the owning node increments on its hot path while the
//! topology driver samples them **mid-run** (the adaptive controllers
//! in [`crate::stream`] re-cut stripes and re-tune chunk sizes from
//! these samples). The end-of-run [`NodeReport`] is reconstructed from
//! a final [`LiveNode::sample`], so every counter keeps its historical
//! meaning.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Per-node counters for one source or sink of a stream topology.
///
/// [`crate::stream::StreamReport`] carries one of these per topology
/// node, so fan-in/fan-out runs can attribute traffic (and stalls) to
/// individual sensors and outputs instead of reporting only edge-level
/// aggregates.
#[derive(Debug, Clone, Default)]
pub struct NodeReport {
    /// Human-readable node description (the node's `describe()`).
    pub name: String,
    /// Events through this node (sources: pulled; sinks: routed in).
    pub events: u64,
    /// Non-empty batches through this node.
    pub batches: u64,
    /// Times a writer found this node's queue full and suspended
    /// (source pump threads / the fan-out router).
    pub backpressure_waits: u64,
    /// Events the node itself discarded (e.g. outside a source's
    /// claimed geometry, or filtered by a pipeline stage; 0 elsewhere).
    pub dropped: u64,
    /// Frames produced (frame-binning sinks; 0 elsewhere).
    pub frames: u64,
    /// Event bytes physically copied into (or by) this node: selection
    /// scatters writing the node's partition, stage chains
    /// materializing their output buffer. Refcounted chunk handoff
    /// contributes nothing — this is the per-node copy-traffic gauge
    /// behind `bytes_moved_per_event`.
    pub bytes_moved: u64,
    /// Whole-batch deep copies made for this node. Zero on the
    /// stateless zero-copy delivery paths (broadcast and stripe/polarity
    /// routing) — asserted by the chunk-semantics tests.
    pub chunks_cloned: u64,
    /// Output buffers this node obtained from the chunk pool's free
    /// list (no allocation).
    pub pool_hits: u64,
    /// Output buffers this node had to allocate fresh (empty pool).
    pub pool_misses: u64,
    /// Disk-buffered edges: journal bytes currently on disk behind this
    /// node (a gauge — the last published value, not a running sum).
    pub buffer_bytes_on_disk: u64,
    /// Disk-buffered edges: records whose in-memory copy was dropped
    /// because the bounded front was full (they drain from disk).
    pub buffer_records_spilled: u64,
    /// Records read back from a disk journal (buffered-edge drains and
    /// replay sources).
    pub buffer_records_replayed: u64,
    /// Records lost to CRC-failed journal frames (bit rot) and skipped.
    pub buffer_corrupt_records_skipped: u64,
    /// Whether spilled batches were still waiting on disk at the last
    /// sample (gauge).
    pub buffer_spill_active: bool,
    /// Sharded stage nodes: home events routed to each shard (ghost
    /// copies excluded). Empty for unsharded nodes. Sums to
    /// [`events`](NodeReport::events).
    pub shard_events: Vec<u64>,
}

impl NodeReport {
    /// Load imbalance across shards: the busiest shard's event count
    /// over the mean across **all** shards, zero-traffic shards
    /// included (an idle stripe *is* imbalance). A skew of N on N
    /// shards means one stripe did all the work — the signal to re-cut
    /// stripes or drop the shard count.
    ///
    /// The value has a **1.0 floor** for every sharded node: the max is
    /// never below the mean, and a sharded node that saw no events at
    /// all (e.g. a filter-heavy chain upstream dropped everything)
    /// reports exactly 1.0 — trivially balanced — instead of a 0/0
    /// artifact. `0.0` is reserved for unsharded nodes, so the two
    /// cases stay distinguishable.
    pub fn shard_skew(&self) -> f64 {
        if self.shard_events.is_empty() {
            return 0.0;
        }
        shard_skew_of(&self.shard_events)
    }
}

/// Skew of a per-shard event histogram: max over mean, with the
/// degenerate all-zero histogram pinned to the 1.0 floor (no traffic is
/// trivially balanced, not 0/0). Shared by [`NodeReport::shard_skew`]
/// and the adaptive controllers, which compute skew over per-epoch
/// histograms before deciding to re-cut.
pub fn shard_skew_of(shard_events: &[u64]) -> f64 {
    if shard_events.is_empty() {
        return 0.0;
    }
    let total: u64 = shard_events.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / shard_events.len() as f64;
    *shard_events.iter().max().expect("nonempty") as f64 / mean
}

// ------------------------------------------------------ telemetry plane

/// Live per-node counters: the mid-run form of [`NodeReport`].
///
/// Scalar counters are atomics — the owning node increments them with
/// relaxed ordering on its hot path (no allocation, no locks) while the
/// topology driver samples the plane between batches. The per-shard
/// histogram sits behind a mutex touched once per *batch* (never per
/// event): it must be resizable when an epoch re-cut changes the stripe
/// layout, and it carries a second, per-epoch lane the controllers
/// drain ([`take_epoch_shards`](LiveNode::take_epoch_shards)) so skew
/// decisions see recent traffic, not the whole run's average.
#[derive(Debug)]
pub struct LiveNode {
    name: String,
    events: AtomicU64,
    batches: AtomicU64,
    backpressure_waits: AtomicU64,
    dropped: AtomicU64,
    bytes_moved: AtomicU64,
    chunks_cloned: AtomicU64,
    pool_hits: AtomicU64,
    pool_misses: AtomicU64,
    buffer_bytes_on_disk: AtomicU64,
    buffer_records_spilled: AtomicU64,
    buffer_records_replayed: AtomicU64,
    buffer_corrupt_records_skipped: AtomicU64,
    buffer_spill_active: AtomicU64,
    shards: Mutex<ShardCells>,
}

/// Per-shard home-event counts: cumulative since the last re-cut (the
/// report lane) and since the last controller sample (the epoch lane).
#[derive(Debug, Default)]
struct ShardCells {
    cut: Vec<u64>,
    epoch: Vec<u64>,
}

impl LiveNode {
    /// Fresh plane cell for a node (unsharded until
    /// [`reset_shards`](LiveNode::reset_shards)).
    pub fn new(name: impl Into<String>) -> Self {
        LiveNode {
            name: name.into(),
            events: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            backpressure_waits: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            bytes_moved: AtomicU64::new(0),
            chunks_cloned: AtomicU64::new(0),
            pool_hits: AtomicU64::new(0),
            pool_misses: AtomicU64::new(0),
            buffer_bytes_on_disk: AtomicU64::new(0),
            buffer_records_spilled: AtomicU64::new(0),
            buffer_records_replayed: AtomicU64::new(0),
            buffer_corrupt_records_skipped: AtomicU64::new(0),
            buffer_spill_active: AtomicU64::new(0),
            shards: Mutex::new(ShardCells::default()),
        }
    }

    /// The node's description.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Count `n` events through the node.
    pub fn add_events(&self, n: u64) {
        self.events.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one non-empty batch.
    pub fn add_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one full-queue suspension writing to this node.
    pub fn add_backpressure_wait(&self) {
        self.backpressure_waits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` events the node itself discarded.
    pub fn add_dropped(&self, n: u64) {
        self.dropped.fetch_add(n, Ordering::Relaxed);
    }

    /// Count `n` event bytes physically copied into/by this node.
    pub fn add_bytes_moved(&self, n: u64) {
        self.bytes_moved.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one whole-batch deep copy made for this node.
    pub fn add_chunk_cloned(&self) {
        self.chunks_cloned.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one pooled-buffer reuse (no allocation) for this node.
    pub fn add_pool_hit(&self) {
        self.pool_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one fresh buffer allocation (pool empty) for this node.
    pub fn add_pool_miss(&self) {
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish a disk-buffer snapshot (buffered edges and replay
    /// sources own these cells; idempotent gauge stores, so re-publish
    /// on every batch is free of double counting).
    pub fn set_buffer_gauges(
        &self,
        bytes_on_disk: u64,
        records_spilled: u64,
        records_replayed: u64,
        corrupt_records_skipped: u64,
        spill_active: bool,
    ) {
        self.buffer_bytes_on_disk.store(bytes_on_disk, Ordering::Relaxed);
        self.buffer_records_spilled.store(records_spilled, Ordering::Relaxed);
        self.buffer_records_replayed.store(records_replayed, Ordering::Relaxed);
        self.buffer_corrupt_records_skipped.store(corrupt_records_skipped, Ordering::Relaxed);
        self.buffer_spill_active.store(u64::from(spill_active), Ordering::Relaxed);
    }

    /// Record one batch's per-shard home-event counts (both lanes).
    pub fn record_shards(&self, homes: &[u64]) {
        let mut cells = self.shards.lock().unwrap();
        if cells.cut.len() != homes.len() {
            cells.cut = vec![0; homes.len()];
            cells.epoch = vec![0; homes.len()];
        }
        for (slot, h) in cells.cut.iter_mut().zip(homes) {
            *slot += h;
        }
        for (slot, h) in cells.epoch.iter_mut().zip(homes) {
            *slot += h;
        }
    }

    /// Re-cut: both shard lanes restart at zero over `n` shards, so the
    /// histogram (and [`NodeReport::shard_events`]) describes traffic
    /// under the *current* stripe cut only.
    pub fn reset_shards(&self, n: usize) {
        let mut cells = self.shards.lock().unwrap();
        cells.cut = vec![0; n];
        cells.epoch = vec![0; n];
    }

    /// Drain the per-epoch shard histogram (controller sampling): the
    /// counts since the previous drain, under the current cut.
    pub fn take_epoch_shards(&self) -> Vec<u64> {
        let mut cells = self.shards.lock().unwrap();
        let out = cells.epoch.clone();
        cells.epoch.iter_mut().for_each(|c| *c = 0);
        out
    }

    /// Snapshot the cumulative counters as a [`NodeReport`]. Idempotent
    /// — safe mid-run and for the final report (shard counts cover the
    /// span since the last re-cut; see
    /// [`reset_shards`](LiveNode::reset_shards)).
    pub fn sample(&self) -> NodeReport {
        NodeReport {
            name: self.name.clone(),
            events: self.events.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            backpressure_waits: self.backpressure_waits.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            frames: 0,
            bytes_moved: self.bytes_moved.load(Ordering::Relaxed),
            chunks_cloned: self.chunks_cloned.load(Ordering::Relaxed),
            pool_hits: self.pool_hits.load(Ordering::Relaxed),
            pool_misses: self.pool_misses.load(Ordering::Relaxed),
            buffer_bytes_on_disk: self.buffer_bytes_on_disk.load(Ordering::Relaxed),
            buffer_records_spilled: self.buffer_records_spilled.load(Ordering::Relaxed),
            buffer_records_replayed: self.buffer_records_replayed.load(Ordering::Relaxed),
            buffer_corrupt_records_skipped: self
                .buffer_corrupt_records_skipped
                .load(Ordering::Relaxed),
            buffer_spill_active: self.buffer_spill_active.load(Ordering::Relaxed) != 0,
            shard_events: self.shards.lock().unwrap().cut.clone(),
        }
    }
}

/// Wall-clock stopwatch with µs readout.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed time.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed microseconds.
    pub fn elapsed_us(&self) -> u64 {
        self.elapsed().as_micros() as u64
    }
}

/// Throughput meter: items per second over the measured span.
#[derive(Debug, Default, Clone)]
pub struct RateMeter {
    items: u64,
    span: Duration,
}

impl RateMeter {
    /// Fresh meter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `n` items processed over `span`.
    pub fn record(&mut self, n: u64, span: Duration) {
        self.items += n;
        self.span += span;
    }

    /// Total items recorded.
    pub fn items(&self) -> u64 {
        self.items
    }

    /// Items per second (0 if nothing recorded).
    pub fn rate(&self) -> f64 {
        if self.span.is_zero() {
            0.0
        } else {
            self.items as f64 / self.span.as_secs_f64()
        }
    }
}

/// Fixed-bucket log-scale duration histogram: 1 µs … ~17 s in 25
/// power-of-two buckets, constant memory, O(1) record.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// buckets[i] counts samples in [2^i, 2^(i+1)) µs.
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 25], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl LatencyHistogram {
    /// Fresh histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration.
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let bucket = (64 - us.max(1).leading_zeros() as usize - 1).min(24);
        self.buckets[bucket] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean in µs.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Maximum recorded µs.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile (bucket upper bound), `q` in [0,1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1 << (i + 1);
            }
        }
        1 << 25
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_meter_computes_rate() {
        let mut m = RateMeter::new();
        m.record(1000, Duration::from_millis(500));
        m.record(1000, Duration::from_millis(500));
        assert_eq!(m.items(), 2000);
        assert!((m.rate() - 2000.0).abs() < 1.0);
    }

    #[test]
    fn empty_meter_rate_is_zero() {
        assert_eq!(RateMeter::new().rate(), 0.0);
    }

    #[test]
    fn histogram_buckets_and_stats() {
        let mut h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 1000, 1000, 1_000_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max_us(), 1_000_000);
        assert!(h.mean_us() > 0.0);
        // Median: 3rd of 6 ordered samples is 4 µs → bucket bound 8 µs.
        let p50 = h.quantile_us(0.5);
        assert!((4..=8).contains(&p50), "p50 = {p50}");
        // p100 covers the max.
        assert!(h.quantile_us(1.0) >= 1_000_000);
    }

    #[test]
    fn shard_skew_measures_imbalance() {
        let mut node = NodeReport::default();
        assert_eq!(node.shard_skew(), 0.0, "unsharded node has no skew");
        node.shard_events = vec![100, 100, 100, 100];
        assert!((node.shard_skew() - 1.0).abs() < 1e-9, "balanced = 1.0");
        node.shard_events = vec![400, 0, 0, 0];
        assert!((node.shard_skew() - 4.0).abs() < 1e-9, "one hot stripe = N");
    }

    /// Regression: a sharded node whose shards all saw zero events
    /// (a filter-heavy chain upstream dropped everything) must report
    /// the documented 1.0 floor — trivially balanced — and never a 0/0
    /// artifact or the unsharded 0.0 sentinel.
    #[test]
    fn shard_skew_all_zero_shards_is_the_floor() {
        let mut node = NodeReport::default();
        node.shard_events = vec![0, 0, 0];
        assert_eq!(node.shard_skew(), 1.0, "no traffic is trivially balanced");
        assert!(node.shard_skew().is_finite());
        // The free function agrees, and keeps 0.0 for "not sharded".
        assert_eq!(shard_skew_of(&[0, 0]), 1.0);
        assert_eq!(shard_skew_of(&[]), 0.0);
        // The floor holds for every non-degenerate histogram too.
        for hist in [&[1u64, 0][..], &[3, 3, 3], &[0, 0, 9, 1]] {
            assert!(shard_skew_of(hist) >= 1.0, "{hist:?}");
        }
    }

    #[test]
    fn live_node_samples_and_epoch_drains() {
        let node = LiveNode::new("stage");
        node.add_events(100);
        node.add_batch();
        node.add_dropped(25);
        node.add_backpressure_wait();
        node.add_bytes_moved(1600);
        node.add_chunk_cloned();
        node.record_shards(&[60, 40]);
        let report = node.sample();
        assert_eq!(report.name, "stage");
        assert_eq!(report.events, 100);
        assert_eq!(report.batches, 1);
        assert_eq!(report.dropped, 25);
        assert_eq!(report.backpressure_waits, 1);
        assert_eq!(report.bytes_moved, 1600);
        assert_eq!(report.chunks_cloned, 1);
        assert_eq!(report.shard_events, vec![60, 40]);
        // The epoch lane drains independently of the cumulative lane.
        assert_eq!(node.take_epoch_shards(), vec![60, 40]);
        assert_eq!(node.take_epoch_shards(), vec![0, 0], "drained");
        node.record_shards(&[1, 2]);
        assert_eq!(node.sample().shard_events, vec![61, 42], "cumulative survives");
        assert_eq!(node.take_epoch_shards(), vec![1, 2]);
        // A re-cut restarts both lanes under the new shard count.
        node.reset_shards(3);
        node.record_shards(&[5, 6, 7]);
        assert_eq!(node.sample().shard_events, vec![5, 6, 7]);
        assert_eq!(node.take_epoch_shards(), vec![5, 6, 7]);
    }

    #[test]
    fn stopwatch_monotonic() {
        let s = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(s.elapsed_us() >= 1000);
    }
}
