//! Cooperative preemption point.

use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll};

/// Suspend the current coroutine once, handing control back to the
/// scheduler (which will resume it on the next sweep). The suspend point
/// is exactly the paper's Fig. 1(B) control transfer.
pub fn yield_now() -> YieldNow {
    YieldNow { yielded: false }
}

/// Future returned by [`yield_now`].
#[derive(Debug)]
pub struct YieldNow {
    yielded: bool,
}

impl Future for YieldNow {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.yielded {
            Poll::Ready(())
        } else {
            self.yielded = true;
            // Mark ourselves immediately ready so the scheduler re-polls
            // us on its next pass, after giving other coroutines a turn.
            cx.waker().wake_by_ref();
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::block_on;

    #[test]
    fn completes_after_one_suspend() {
        block_on(async {
            yield_now().await;
            yield_now().await;
        });
    }
}
