//! Waker implementations for the runtime.
//!
//! Two flavours:
//! * [`thread_waker`] — unparks a thread; used by [`crate::rt::block_on`].
//! * [`flag_waker`] — sets an atomic flag; used by the run-queue executor
//!   to mark a task as ready without any thread interaction (the
//!   zero-synchronization path the paper's coroutines rely on).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::task::{RawWaker, RawWakerVTable, Waker};
use std::thread::Thread;

// ---------------------------------------------------------------------
// Thread waker: wake = unpark.
// ---------------------------------------------------------------------

unsafe fn thread_clone(data: *const ()) -> RawWaker {
    let arc = Arc::from_raw(data as *const Thread);
    std::mem::forget(arc.clone());
    let ptr = Arc::into_raw(arc) as *const ();
    RawWaker::new(ptr, &THREAD_VTABLE)
}

unsafe fn thread_wake(data: *const ()) {
    let arc = Arc::from_raw(data as *const Thread);
    arc.unpark();
}

unsafe fn thread_wake_by_ref(data: *const ()) {
    let thread = &*(data as *const Thread);
    thread.unpark();
}

unsafe fn thread_drop(data: *const ()) {
    drop(Arc::from_raw(data as *const Thread));
}

static THREAD_VTABLE: RawWakerVTable =
    RawWakerVTable::new(thread_clone, thread_wake, thread_wake_by_ref, thread_drop);

/// A waker that unparks `thread` when woken.
pub fn thread_waker(thread: Thread) -> Waker {
    let ptr = Arc::into_raw(Arc::new(thread)) as *const ();
    unsafe { Waker::from_raw(RawWaker::new(ptr, &THREAD_VTABLE)) }
}

// ---------------------------------------------------------------------
// Flag waker: wake = store(true). No parking, no locks.
// ---------------------------------------------------------------------

unsafe fn flag_clone(data: *const ()) -> RawWaker {
    let arc = Arc::from_raw(data as *const AtomicBool);
    std::mem::forget(arc.clone());
    let ptr = Arc::into_raw(arc) as *const ();
    RawWaker::new(ptr, &FLAG_VTABLE)
}

unsafe fn flag_wake(data: *const ()) {
    let arc = Arc::from_raw(data as *const AtomicBool);
    arc.store(true, Ordering::Release);
}

unsafe fn flag_wake_by_ref(data: *const ()) {
    let flag = &*(data as *const AtomicBool);
    flag.store(true, Ordering::Release);
}

unsafe fn flag_drop(data: *const ()) {
    drop(Arc::from_raw(data as *const AtomicBool));
}

static FLAG_VTABLE: RawWakerVTable =
    RawWakerVTable::new(flag_clone, flag_wake, flag_wake_by_ref, flag_drop);

/// A waker that sets `flag` (with `Release` ordering) when woken.
pub fn flag_waker(flag: Arc<AtomicBool>) -> Waker {
    let ptr = Arc::into_raw(flag) as *const ();
    unsafe { Waker::from_raw(RawWaker::new(ptr, &FLAG_VTABLE)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_waker_sets_flag() {
        let flag = Arc::new(AtomicBool::new(false));
        let w = flag_waker(flag.clone());
        assert!(!flag.load(Ordering::Acquire));
        w.wake_by_ref();
        assert!(flag.load(Ordering::Acquire));
        flag.store(false, Ordering::Release);
        let w2 = w.clone();
        w2.wake(); // consuming wake
        assert!(flag.load(Ordering::Acquire));
        drop(w);
    }

    #[test]
    fn flag_waker_refcount_balanced() {
        let flag = Arc::new(AtomicBool::new(false));
        {
            let w = flag_waker(flag.clone());
            let w2 = w.clone();
            let w3 = w2.clone();
            w3.wake();
            drop(w2);
            drop(w);
        }
        // All raw-waker clones released: only our handle remains.
        assert_eq!(Arc::strong_count(&flag), 1);
    }

    #[test]
    fn thread_waker_unparks() {
        let handle = std::thread::spawn(|| {
            std::thread::park();
            42
        });
        // Give the thread a moment to park, then wake it via the waker.
        std::thread::sleep(std::time::Duration::from_millis(10));
        let w = thread_waker(handle.thread().clone());
        w.wake();
        assert_eq!(handle.join().unwrap(), 42);
    }
}
