//! Cross-thread async channel: lock-free SPSC ring + waker slots.
//!
//! Used when a coroutine pipeline spans threads (e.g. a camera/UDP
//! reader thread feeding a processing executor). The data path is the
//! wait-free [`crate::sync::spsc`] ring; a mutex is touched only on the
//! empty/full edges to park and wake the opposing side, never per event
//! in steady state — preserving the paper's "no per-event locks"
//! property while staying sound across threads.

use std::future::Future;
use std::pin::Pin;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};

use crate::sync::spsc::{spsc_ring, RingConsumer, RingProducer};

/// Waker mailboxes for the two sides. Locked only when a side is about
/// to suspend or has just crossed an empty/full edge.
#[derive(Default)]
struct Shared {
    recv_waker: Mutex<Option<Waker>>,
    send_waker: Mutex<Option<Waker>>,
}

impl Shared {
    fn wake_recv(&self) {
        if let Some(w) = self.recv_waker.lock().unwrap().take() {
            w.wake();
        }
    }
    fn wake_send(&self) {
        if let Some(w) = self.send_waker.lock().unwrap().take() {
            w.wake();
        }
    }
}

/// Sending half (single producer).
pub struct SyncSender<T: Send> {
    ring: RingProducer<T>,
    shared: Arc<Shared>,
}

/// Receiving half (single consumer).
pub struct SyncReceiver<T: Send> {
    ring: RingConsumer<T>,
    shared: Arc<Shared>,
}

/// Create a bounded cross-thread async channel with capacity `cap`
/// (rounded up to a power of two).
pub fn sync_channel<T: Send>(cap: usize) -> (SyncSender<T>, SyncReceiver<T>) {
    let (p, c) = spsc_ring(cap);
    let shared = Arc::new(Shared::default());
    (
        SyncSender { ring: p, shared: shared.clone() },
        SyncReceiver { ring: c, shared },
    )
}

impl<T: Send> SyncSender<T> {
    /// Send an item, suspending while the ring is full.
    /// Returns `Err(item)` if the receiver was dropped.
    pub async fn send(&mut self, item: T) -> Result<(), T> {
        let mut item = Some(item);
        std::future::poll_fn(move |cx| {
            let it = item.take().expect("polled after completion");
            match self.try_send_inner(it) {
                Ok(()) => Poll::Ready(Ok(())),
                Err(TrySend::Closed(it)) => Poll::Ready(Err(it)),
                Err(TrySend::Full(it)) => {
                    item = Some(it);
                    *self.shared.send_waker.lock().unwrap() = Some(cx.waker().clone());
                    // Re-check after registering: the consumer may have
                    // drained between our try and the registration.
                    let it = item.take().unwrap();
                    match self.try_send_inner(it) {
                        Ok(()) => {
                            self.shared.send_waker.lock().unwrap().take();
                            Poll::Ready(Ok(()))
                        }
                        Err(TrySend::Closed(it)) => Poll::Ready(Err(it)),
                        Err(TrySend::Full(it)) => {
                            item = Some(it);
                            Poll::Pending
                        }
                    }
                }
            }
        })
        .await
    }

    /// Non-suspending send attempt.
    pub fn try_send(&mut self, item: T) -> Result<(), T> {
        match self.try_send_inner(item) {
            Ok(()) => Ok(()),
            Err(TrySend::Full(i)) | Err(TrySend::Closed(i)) => Err(i),
        }
    }

    fn try_send_inner(&mut self, item: T) -> Result<(), TrySend<T>> {
        // Check liveness *first*: a dropped receiver drains the ring on
        // drop, so a post-hoc "full" check would let sends silently
        // succeed into the void.
        if self.receiver_gone() {
            return Err(TrySend::Closed(item));
        }
        match self.ring.try_push(item) {
            Ok(()) => {
                self.shared.wake_recv();
                Ok(())
            }
            Err(item) => Err(TrySend::Full(item)),
        }
    }

    fn receiver_gone(&self) -> bool {
        Arc::strong_count(&self.shared) == 1
    }
}

enum TrySend<T> {
    Full(T),
    Closed(T),
}

impl<T: Send> Drop for SyncSender<T> {
    fn drop(&mut self) {
        // Publish the close *before* waking, otherwise a receiver could
        // wake, observe "not closed", re-park, and miss the shutdown.
        self.ring.close();
        self.shared.wake_recv();
    }
}

impl<T: Send> Drop for SyncReceiver<T> {
    fn drop(&mut self) {
        self.shared.wake_send();
    }
}

impl<T: Send> SyncReceiver<T> {
    /// Receive the next item, suspending while the ring is empty.
    /// Resolves to `None` once the sender is dropped and the ring drained.
    pub fn recv(&mut self) -> RecvFut<'_, T> {
        RecvFut { rx: self }
    }

    /// Non-suspending receive attempt.
    pub fn try_recv(&mut self) -> Option<T> {
        let item = self.ring.try_pop();
        if item.is_some() {
            self.shared.wake_send();
        }
        item
    }

    /// `true` once the sender has been dropped. Items pushed before the
    /// close may still be pending: poll [`try_recv`](Self::try_recv)
    /// once more after observing the close to drain them (the same
    /// drain-then-close protocol [`recv`](Self::recv) follows).
    pub fn is_closed(&self) -> bool {
        self.ring.is_closed()
    }
}

/// Future returned by [`SyncReceiver::recv`].
pub struct RecvFut<'r, T: Send> {
    rx: &'r mut SyncReceiver<T>,
}

impl<T: Send> Future for RecvFut<'_, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Option<T>> {
        let rx = &mut self.get_mut().rx;
        if let Some(item) = rx.try_recv() {
            return Poll::Ready(Some(item));
        }
        if rx.ring.is_closed() {
            // Drain-then-close: one more pop attempt after seeing closed.
            return Poll::Ready(rx.try_recv());
        }
        *rx.shared.recv_waker.lock().unwrap() = Some(cx.waker().clone());
        // Re-check after registering to close the lost-wake window.
        if let Some(item) = rx.try_recv() {
            rx.shared.recv_waker.lock().unwrap().take();
            return Poll::Ready(Some(item));
        }
        if rx.ring.is_closed() {
            return Poll::Ready(rx.try_recv());
        }
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::block_on;

    #[test]
    fn cross_thread_stream_drains_fully() {
        let (mut tx, mut rx) = sync_channel::<u64>(16);
        let n = 10_000u64;
        let producer = std::thread::spawn(move || {
            block_on(async move {
                for i in 0..n {
                    tx.send(i).await.unwrap();
                }
            });
        });
        let sum = block_on(async {
            let mut sum = 0u64;
            while let Some(v) = rx.recv().await {
                sum += v;
            }
            sum
        });
        assert_eq!(sum, n * (n - 1) / 2);
        producer.join().unwrap();
    }

    #[test]
    fn recv_none_after_sender_drop() {
        let (mut tx, mut rx) = sync_channel::<u32>(4);
        tx.try_send(1).unwrap();
        drop(tx);
        assert_eq!(block_on(rx.recv()), Some(1));
        assert_eq!(block_on(rx.recv()), None);
    }

    #[test]
    fn send_err_after_receiver_drop() {
        let (mut tx, rx) = sync_channel::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        drop(rx);
        // Ring full and receiver gone: must resolve to Err, not hang.
        assert_eq!(block_on(tx.send(3)), Err(3));
    }

    #[test]
    fn try_send_full_returns_item() {
        let (mut tx, mut rx) = sync_channel::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(3));
        assert_eq!(rx.try_recv(), Some(1));
        tx.try_send(3).unwrap();
    }
}
