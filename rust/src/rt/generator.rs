//! Pull-based coroutine generator: the C++20 symmetric-transfer analog.
//!
//! The paper's Fig. 1(B) coroutines hand single events from producer to
//! consumer "with an overhead comparable to a regular function call".
//! The C++20 mechanism is symmetric transfer: resuming the consumer
//! *is* a jump, no scheduler involved. The Rust equivalent is a
//! **generator**: the producer is an `async fn` state machine that the
//! consumer polls directly — each `next()` is one (devirtualized,
//! inlineable) `poll` that advances the producer exactly one `yield`.
//!
//! No executor, no channel, no wakers (a noop waker is passed because
//! `poll` demands one): per-event cost is the state-machine advance plus
//! one `Cell` swap. This is what [`crate::engine::coro`] benchmarks in
//! Fig. 3; the executor-based form ([`crate::rt::LocalExecutor`] +
//! channels) is what pipelines with real concurrent I/O use.

use std::cell::Cell;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

/// The producer side: `y.yield_item(v).await` suspends the coroutine
/// and transfers control (the value) to the consumer's `next()`.
pub struct Yielder<T> {
    slot: Rc<Cell<Option<T>>>,
}

impl<T> Yielder<T> {
    /// Yield one item to the consumer. The returned future completes on
    /// the *next* poll (after the consumer took the item).
    pub fn yield_item(&self, item: T) -> YieldFut<'_, T> {
        YieldFut { slot: &self.slot, item: Some(item) }
    }
}

/// Future returned by [`Yielder::yield_item`].
pub struct YieldFut<'y, T> {
    slot: &'y Rc<Cell<Option<T>>>,
    item: Option<T>,
}

impl<T> Unpin for YieldFut<'_, T> {}

impl<T> Future for YieldFut<'_, T> {
    type Output = ();

    #[inline]
    fn poll(mut self: Pin<&mut Self>, _cx: &mut Context<'_>) -> Poll<()> {
        match self.item.take() {
            Some(item) => {
                // First poll: publish the item and suspend. No waker —
                // the consumer polls us again by construction.
                self.slot.set(Some(item));
                Poll::Pending
            }
            None => Poll::Ready(()),
        }
    }
}

/// A coroutine generator over items of type `T`.
///
/// ```
/// use aestream::rt::generator::Generator;
/// let data = [1u64, 2, 3];
/// let mut gen = Generator::new(|y| async move {
///     for &v in &data {
///         y.yield_item(v * 10).await;
///     }
/// });
/// assert_eq!(gen.next(), Some(10));
/// assert_eq!(gen.next(), Some(20));
/// assert_eq!(gen.next(), Some(30));
/// assert_eq!(gen.next(), None);
/// ```
pub struct Generator<'a, T> {
    fut: Pin<Box<dyn Future<Output = ()> + 'a>>,
    slot: Rc<Cell<Option<T>>>,
    done: bool,
}

impl<'a, T: 'a> Generator<'a, T> {
    /// Create a generator from an async closure over a [`Yielder`].
    /// The single `Box::pin` is the coroutine frame allocation (C++20
    /// heap-allocates the frame the same way).
    pub fn new<F, Fut>(f: F) -> Self
    where
        F: FnOnce(Yielder<T>) -> Fut,
        Fut: Future<Output = ()> + 'a,
    {
        let slot = Rc::new(Cell::new(None));
        let fut = Box::pin(f(Yielder { slot: slot.clone() }));
        Generator { fut, slot, done: false }
    }

    /// Resume the coroutine until it yields the next item (or finishes).
    #[inline]
    pub fn next(&mut self) -> Option<T> {
        if self.done {
            return None;
        }
        let waker = Waker::noop();
        let mut cx = Context::from_waker(waker);
        match self.fut.as_mut().poll(&mut cx) {
            Poll::Pending => self.slot.take(),
            Poll::Ready(()) => {
                self.done = true;
                // A final item may have been yielded right before return.
                self.slot.take()
            }
        }
    }
}

impl<T> Iterator for Generator<'_, T>
where
    T: 'static,
{
    type Item = T;
    fn next(&mut self) -> Option<T> {
        Generator::next(self)
    }
}

/// Zero-dispatch generator drive: stack-pin the coroutine frame and poll
/// it with a *concrete* future type, so the compiler inlines the resume
/// into the consumer loop — this is the true analog of C++20 symmetric
/// transfer, where resuming the next coroutine is a plain jump.
///
/// [`Generator`] (boxed, type-erased) pays a virtual call per item;
/// `drive` pays none. The Fig. 3 engine uses `drive`.
#[inline]
pub fn drive<T, MkFut, Fut, F>(mk: MkFut, mut consume: F)
where
    MkFut: FnOnce(Yielder<T>) -> Fut,
    Fut: Future<Output = ()>,
    F: FnMut(T),
{
    let slot = Rc::new(Cell::new(None));
    let fut = mk(Yielder { slot: slot.clone() });
    let mut fut = std::pin::pin!(fut);
    let waker = Waker::noop();
    let mut cx = Context::from_waker(waker);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Pending => {
                if let Some(item) = slot.take() {
                    consume(item);
                }
            }
            Poll::Ready(()) => {
                if let Some(item) = slot.take() {
                    consume(item);
                }
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yields_all_items_in_order() {
        let mut gen = Generator::new(|y| async move {
            for i in 0..100u32 {
                y.yield_item(i).await;
            }
        });
        for i in 0..100 {
            assert_eq!(gen.next(), Some(i));
        }
        assert_eq!(gen.next(), None);
        assert_eq!(gen.next(), None, "post-completion polls are safe");
    }

    #[test]
    fn empty_generator() {
        let mut gen = Generator::<u32>::new(|_y| async move {});
        assert_eq!(gen.next(), None);
    }

    #[test]
    fn borrows_external_data() {
        let data = vec![5u64, 6, 7];
        let mut gen = Generator::new(|y| {
            let data = &data;
            async move {
                for &v in data {
                    y.yield_item(v).await;
                }
            }
        });
        assert_eq!(gen.by_ref().count(), 3);
    }

    #[test]
    fn drive_matches_generator() {
        let data: Vec<u32> = (0..1000).collect();
        let mut out = Vec::new();
        drive(
            |y| {
                let data = &data;
                async move {
                    for &v in data {
                        y.yield_item(v).await;
                    }
                }
            },
            |v| out.push(v),
        );
        assert_eq!(out, data);
    }

    #[test]
    fn nested_compute_between_yields() {
        // The coroutine can do arbitrary work between yields; control
        // still alternates strictly.
        let mut gen = Generator::new(|y| async move {
            let mut acc = 0u64;
            for i in 1..=10u64 {
                acc += i;
                if acc % 2 == 0 {
                    y.yield_item(acc).await;
                }
            }
        });
        let collected: Vec<u64> = std::iter::from_fn(|| gen.next()).collect();
        assert_eq!(collected, vec![6, 10, 28, 36]);
    }
}
