//! Minimal cooperative async runtime — the "coroutine" substrate.
//!
//! The paper's contribution rests on C++20 *stackless coroutines*:
//! functions that suspend and resume with function-call-like overhead,
//! passing control (and single events) without centralized
//! synchronization. Rust's `async fn` compiles to exactly the same
//! artifact — a stackless state machine resumed via [`Future::poll`] —
//! so this module provides the scheduling substrate that C++20 leaves to
//! the library author, built from scratch (no tokio):
//!
//! * [`Generator`] — pull-based coroutine with direct control transfer
//!   (the C++20 symmetric-transfer analog; per-item cost ≈ a function
//!   call — the Fig. 3 contender);
//! * [`block_on`] — drive a single future to completion on the current
//!   thread (parking when pending);
//! * [`LocalExecutor`] — a single-threaded, run-queue based cooperative
//!   executor: the direct analog of the paper's Fig. 1(B), where control
//!   is transferred between coroutines without locks;
//! * [`channel`] — single-threaded async channels for event handoff at
//!   per-event granularity (the anti-buffer primitive);
//! * [`sync_channel`] — a thread-safe async MPSC channel used when
//!   coroutines hop threads;
//! * [`yield_now`] — cooperative preemption point.
//!
//! Everything is intentionally small and auditable: the Fig. 3 benchmark
//! measures this machinery, so it must not hide locks.

pub mod block_on;
pub mod channel;
pub mod executor;
pub mod generator;
pub mod sync_channel;
pub mod waker;
pub mod yield_now;

pub use block_on::block_on;
pub use channel::{channel, Receiver, RecvError, SendError, Sender};
pub use executor::LocalExecutor;
pub use generator::{Generator, Yielder};
pub use sync_channel::{sync_channel, SyncReceiver, SyncSender};
pub use yield_now::yield_now;
