//! Single-threaded bounded async channel: per-event coroutine handoff.
//!
//! This is the anti-buffer primitive of the paper: instead of filling a
//! lock-guarded buffer (Fig. 1A), the producer coroutine suspends the
//! moment the consumer is behind, and control transfers with
//! function-call-like overhead. With capacity 1 this is a rendezvous
//! cell; larger capacities let the scheduler amortize task switches
//! without introducing locks (the queue is `Rc<RefCell<…>>`, only ever
//! touched from the owning thread).
//!
//! For cross-thread handoff see [`crate::rt::sync_channel`].

use std::cell::RefCell;
use std::collections::VecDeque;
use std::fmt;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

struct Inner<T> {
    queue: VecDeque<T>,
    cap: usize,
    /// Consumer waiting for an item.
    recv_waker: Option<Waker>,
    /// Producers waiting for space.
    send_wakers: Vec<Waker>,
    senders: usize,
    receiver_alive: bool,
}

impl<T> Inner<T> {
    fn wake_recv(&mut self) {
        if let Some(w) = self.recv_waker.take() {
            w.wake();
        }
    }
    fn wake_senders(&mut self) {
        for w in self.send_wakers.drain(..) {
            w.wake();
        }
    }
}

/// Sending half. Clonable (MPSC within one thread).
pub struct Sender<T> {
    inner: Rc<RefCell<Inner<T>>>,
}

/// Receiving half.
pub struct Receiver<T> {
    inner: Rc<RefCell<Inner<T>>>,
}

/// Error returned when sending into a channel whose receiver is gone.
/// Carries the rejected item back to the caller.
pub struct SendError<T>(pub T);

impl<T> fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("SendError(..)")
    }
}

/// Error returned by [`Receiver::recv`] once all senders are dropped and
/// the queue is drained — represented as `None` from `recv`.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Create a bounded channel with capacity `cap` (min 1).
pub fn channel<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    let inner = Rc::new(RefCell::new(Inner {
        queue: VecDeque::with_capacity(cap.max(1)),
        cap: cap.max(1),
        recv_waker: None,
        send_wakers: Vec::new(),
        senders: 1,
        receiver_alive: true,
    }));
    (Sender { inner: inner.clone() }, Receiver { inner })
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.borrow_mut().senders += 1;
        Sender { inner: self.inner.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut inner = self.inner.borrow_mut();
        inner.senders -= 1;
        if inner.senders == 0 {
            // Let a suspended consumer observe the close.
            inner.wake_recv();
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut inner = self.inner.borrow_mut();
        inner.receiver_alive = false;
        // Unblock all suspended producers so they can fail fast.
        inner.wake_senders();
    }
}

impl<T> Sender<T> {
    /// Send an item, suspending while the channel is full.
    pub fn send(&self, item: T) -> SendFuture<'_, T> {
        SendFuture { sender: self, item: Some(item) }
    }

    /// Non-suspending send attempt. `Err` carries the item back, tagged
    /// with whether the failure is fatal (receiver dropped) or transient
    /// (full).
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut inner = self.inner.borrow_mut();
        if !inner.receiver_alive {
            return Err(TrySendError::Closed(item));
        }
        if inner.queue.len() == inner.cap {
            return Err(TrySendError::Full(item));
        }
        inner.queue.push_back(item);
        inner.wake_recv();
        Ok(())
    }
}

/// Error for [`Sender::try_send`].
#[derive(Debug)]
pub enum TrySendError<T> {
    /// Channel at capacity; retry after the consumer catches up.
    Full(T),
    /// Receiver dropped; the channel is dead.
    Closed(T),
}

/// Future returned by [`Sender::send`].
pub struct SendFuture<'s, T> {
    sender: &'s Sender<T>,
    item: Option<T>,
}

// The future only takes `item` out of the Option and never relies on its
// own address; safe to be Unpin irrespective of `T`.
impl<T> Unpin for SendFuture<'_, T> {}

impl<T> Future for SendFuture<'_, T> {
    type Output = Result<(), SendError<T>>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        // SAFETY-free projection: we never move out of `self` structurally;
        // `item` is an Option we take from.
        let this = self.get_mut();
        let item = this.item.take().expect("SendFuture polled after completion");
        match this.sender.try_send(item) {
            Ok(()) => Poll::Ready(Ok(())),
            Err(TrySendError::Closed(item)) => Poll::Ready(Err(SendError(item))),
            Err(TrySendError::Full(item)) => {
                this.item = Some(item);
                this.sender.inner.borrow_mut().send_wakers.push(cx.waker().clone());
                Poll::Pending
            }
        }
    }
}

impl<T> Receiver<T> {
    /// Receive the next item, suspending while the channel is empty.
    /// Resolves to `None` once every sender is dropped and the queue is
    /// drained.
    pub fn recv(&mut self) -> RecvFuture<'_, T> {
        RecvFuture { receiver: self }
    }

    /// Non-suspending receive attempt.
    pub fn try_recv(&mut self) -> Option<T> {
        let mut inner = self.inner.borrow_mut();
        let item = inner.queue.pop_front();
        if item.is_some() {
            inner.wake_senders();
        }
        item
    }

    /// `true` once all senders are gone and the queue is empty.
    pub fn is_terminated(&self) -> bool {
        let inner = self.inner.borrow();
        inner.senders == 0 && inner.queue.is_empty()
    }
}

/// Future returned by [`Receiver::recv`].
pub struct RecvFuture<'r, T> {
    receiver: &'r mut Receiver<T>,
}

impl<T> Future for RecvFuture<'_, T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let this = self.get_mut();
        let mut inner = this.receiver.inner.borrow_mut();
        if let Some(item) = inner.queue.pop_front() {
            inner.wake_senders();
            return Poll::Ready(Some(item));
        }
        if inner.senders == 0 {
            return Poll::Ready(None);
        }
        inner.recv_waker = Some(cx.waker().clone());
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::{block_on, LocalExecutor};
    use std::cell::Cell;

    #[test]
    fn try_send_try_recv_fifo() {
        let (tx, mut rx) = channel(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert!(matches!(tx.try_send(3), Err(TrySendError::Full(3))));
        assert_eq!(rx.try_recv(), Some(1));
        assert_eq!(rx.try_recv(), Some(2));
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn recv_none_after_senders_dropped() {
        let (tx, mut rx) = channel::<u32>(1);
        tx.try_send(9).unwrap();
        drop(tx);
        assert_eq!(block_on(rx.recv()), Some(9));
        assert_eq!(block_on(rx.recv()), None);
        assert!(rx.is_terminated());
    }

    #[test]
    fn send_fails_once_receiver_dropped() {
        let (tx, rx) = channel::<u32>(1);
        drop(rx);
        assert!(block_on(tx.send(5)).is_err());
        assert!(matches!(tx.try_send(6), Err(TrySendError::Closed(6))));
    }

    #[test]
    fn rendezvous_capacity_one_ping_pong() {
        let got = RefCell::new(Vec::new());
        let got_ref = &got;
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel(1);
        ex.spawn(async move {
            for i in 0..50u32 {
                tx.send(i).await.unwrap();
            }
        });
        ex.spawn(async move {
            while let Some(v) = rx.recv().await {
                got_ref.borrow_mut().push(v);
            }
        });
        ex.run();
        assert_eq!(*got.borrow(), (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn multiple_senders_all_drain() {
        let total = Cell::new(0u64);
        let n_seen = Cell::new(0u32);
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel(4);
        for s in 0..3u64 {
            let tx = tx.clone();
            ex.spawn(async move {
                for i in 0..10u64 {
                    tx.send(s * 100 + i).await.unwrap();
                }
            });
        }
        drop(tx);
        let (total_ref, n_ref) = (&total, &n_seen);
        ex.spawn(async move {
            while let Some(v) = rx.recv().await {
                total_ref.set(total_ref.get() + v);
                n_ref.set(n_ref.get() + 1);
            }
        });
        ex.run();
        assert_eq!(n_seen.get(), 30);
        // 3 senders × Σ(0..10) + (0+100+200)×10
        assert_eq!(total.get(), 3 * 45 + 3000);
    }
}
