//! Single-threaded cooperative executor: the Fig. 1(B) scheduler.
//!
//! Tasks are stackless coroutines (`Future`s). The executor keeps a
//! ready-queue and polls tasks round-robin; a task that suspends
//! (`Poll::Pending`) is parked until its waker fires. Wakers set a
//! per-task atomic flag — no locks, no condvars — so transferring control
//! between a producer and a consumer coroutine costs two `poll` calls and
//! two uncontended atomic stores, which is the "overhead comparable to a
//! regular function call" the paper claims for C++20 coroutines.

use std::cell::RefCell;
use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::task::{Context, Poll, Waker};

use super::waker::flag_waker;

struct Task<'a> {
    future: Pin<Box<dyn Future<Output = ()> + 'a>>,
    ready: Arc<AtomicBool>,
    waker: Waker,
}

/// A single-threaded cooperative executor.
///
/// Futures spawned onto the executor may borrow data that outlives it
/// (lifetime `'a`), which lets the Fig. 3 benchmark stream borrowed event
/// slices through coroutines without copying.
///
/// ```
/// use aestream::rt::LocalExecutor;
/// let data = vec![1u64, 2, 3];
/// let ex = LocalExecutor::new();
/// ex.spawn(async {
///     let s: u64 = data.iter().sum();
///     assert_eq!(s, 6);
/// });
/// ex.run();
/// ```
///
/// Note: data borrowed by spawned coroutines must outlive the executor
/// (declare it first), since the executor owns the suspended state
/// machines until they complete.
#[derive(Default)]
pub struct LocalExecutor<'a> {
    /// Tasks currently owned by the executor. Slots are `None` once the
    /// task completed.
    tasks: RefCell<Vec<Option<Task<'a>>>>,
    /// Tasks spawned while `run` is mid-iteration (re-entrant spawns).
    incoming: RefCell<Vec<Task<'a>>>,
}

impl<'a> LocalExecutor<'a> {
    /// Create an empty executor.
    pub fn new() -> Self {
        Self::default()
    }

    /// Spawn a coroutine onto the executor. The task starts ready and
    /// runs when [`run`](Self::run) is (or already is) driving the queue.
    pub fn spawn<F>(&self, fut: F)
    where
        F: Future<Output = ()> + 'a,
    {
        let ready = Arc::new(AtomicBool::new(true));
        let waker = flag_waker(ready.clone());
        let task = Task { future: Box::pin(fut), ready, waker };
        // `tasks` may be borrowed by `run`; stage re-entrant spawns.
        match self.tasks.try_borrow_mut() {
            Ok(mut tasks) => tasks.push(Some(task)),
            Err(_) => self.incoming.borrow_mut().push(task),
        }
    }

    /// Number of live (uncompleted) tasks.
    pub fn live_tasks(&self) -> usize {
        self.tasks.borrow().iter().filter(|t| t.is_some()).count()
            + self.incoming.borrow().len()
    }

    /// Drive all tasks to completion.
    ///
    /// Returns the number of tasks completed. If every remaining task is
    /// suspended and none can be woken from this thread, the executor
    /// parks briefly and re-checks — this allows wakes from other threads
    /// (e.g. a [`crate::rt::sync_channel`] fed by a camera thread).
    pub fn run(&self) -> usize {
        let mut completed = 0;
        loop {
            let mut progressed = false;
            let mut remaining = 0;
            let n = self.tasks.borrow().len();
            for i in 0..n {
                // Take the task out of its slot so the borrow on `tasks`
                // is released while polling (polls can spawn).
                let taken = {
                    let mut tasks = self.tasks.borrow_mut();
                    match tasks[i] {
                        Some(ref t) if t.ready.swap(false, Ordering::Acquire) => tasks[i].take(),
                        Some(_) => {
                            remaining += 1;
                            None
                        }
                        None => None,
                    }
                };
                let Some(mut task) = taken else { continue };
                progressed = true;
                let mut cx = Context::from_waker(&task.waker);
                match task.future.as_mut().poll(&mut cx) {
                    Poll::Ready(()) => completed += 1,
                    Poll::Pending => {
                        remaining += 1;
                        self.tasks.borrow_mut()[i] = Some(task);
                    }
                }
            }
            // Fold in tasks spawned during polling.
            {
                let mut incoming = self.incoming.borrow_mut();
                if !incoming.is_empty() {
                    progressed = true;
                    remaining += incoming.len();
                    self.tasks.borrow_mut().extend(incoming.drain(..).map(Some));
                }
            }
            if remaining == 0 {
                return completed;
            }
            if !progressed {
                // All tasks suspended; wait for an external wake. A short
                // sleep keeps this correct (if pessimistic) without
                // wiring per-executor parking into every waker.
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::{channel, yield_now};
    use std::cell::Cell;

    #[test]
    fn runs_single_task() {
        let hit = Cell::new(false);
        let ex = LocalExecutor::new();
        let hit_ref = &hit;
        ex.spawn(async move {
            hit_ref.set(true);
        });
        assert_eq!(ex.run(), 1);
        assert!(hit.get());
    }

    #[test]
    fn interleaves_cooperative_tasks() {
        // Two coroutines appending to a shared trace must interleave at
        // yield points — the Fig. 1(B) control transfer.
        let trace = RefCell::new(Vec::new());
        let ex = LocalExecutor::new();
        ex.spawn(async {
            for i in 0..3 {
                trace.borrow_mut().push(format!("a{i}"));
                yield_now().await;
            }
        });
        ex.spawn(async {
            for i in 0..3 {
                trace.borrow_mut().push(format!("b{i}"));
                yield_now().await;
            }
        });
        ex.run();
        let t = trace.borrow();
        assert_eq!(*t, ["a0", "b0", "a1", "b1", "a2", "b2"]);
    }

    #[test]
    fn producer_consumer_pair() {
        let sum = Cell::new(0u64);
        let ex = LocalExecutor::new();
        let (tx, mut rx) = channel::<u64>(1);
        ex.spawn(async move {
            for i in 0..100 {
                tx.send(i).await.unwrap();
            }
        });
        let sum_ref = &sum;
        ex.spawn(async move {
            while let Some(v) = rx.recv().await {
                sum_ref.set(sum_ref.get() + v);
            }
        });
        assert_eq!(ex.run(), 2);
        assert_eq!(sum.get(), 4950);
    }

    #[test]
    fn run_with_no_tasks_returns_zero() {
        let ex = LocalExecutor::new();
        assert_eq!(ex.run(), 0);
    }
}
