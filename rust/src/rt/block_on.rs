//! Drive a future to completion on the current thread.

use std::future::Future;
use std::pin::pin;
use std::task::{Context, Poll};

use super::waker::thread_waker;

/// Run `fut` to completion, parking the current thread while the future
/// is pending. This is the entry point from synchronous code (CLI, tests,
/// benches) into coroutine land.
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let mut fut = pin!(fut);
    let waker = thread_waker(std::thread::current());
    let mut cx = Context::from_waker(&waker);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(out) => return out,
            // A spurious unpark is possible (the platform permits it), so
            // re-poll in a loop rather than asserting on wake causality.
            Poll::Pending => std::thread::park(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rt::yield_now;

    #[test]
    fn immediate_future() {
        assert_eq!(block_on(async { 7 }), 7);
    }

    #[test]
    fn future_that_yields() {
        let out = block_on(async {
            let mut acc = 0;
            for i in 0..10 {
                acc += i;
                yield_now().await;
            }
            acc
        });
        assert_eq!(out, 45);
    }

    #[test]
    fn future_woken_from_another_thread() {
        use std::sync::mpsc;
        use std::task::Waker;

        // A tiny one-shot future: pending until another thread sends.
        struct OneShot {
            rx: mpsc::Receiver<u32>,
            waker_tx: mpsc::Sender<Waker>,
            registered: bool,
        }
        impl Future for OneShot {
            type Output = u32;
            fn poll(mut self: std::pin::Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<u32> {
                if let Ok(v) = self.rx.try_recv() {
                    return Poll::Ready(v);
                }
                if !self.registered {
                    self.waker_tx.send(cx.waker().clone()).unwrap();
                    self.registered = true;
                }
                Poll::Pending
            }
        }

        let (tx, rx) = mpsc::channel();
        let (waker_tx, waker_rx) = mpsc::channel();
        let t = std::thread::spawn(move || {
            let waker: Waker = waker_rx.recv().unwrap();
            tx.send(99).unwrap();
            waker.wake();
        });
        let v = block_on(OneShot { rx, waker_tx, registered: false });
        assert_eq!(v, 99);
        t.join().unwrap();
    }
}
