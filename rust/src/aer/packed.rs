//! Packed 64-bit event encoding used for RAM-cached event arrays and the
//! raw on-disk format.
//!
//! The Fig. 3 benchmark of the paper reads "from a massive event array
//! cached in random access memory (RAM) to avoid delays from disk I/O".
//! Caching 90 M events as 16-byte structs costs 1.4 GB; the packed form
//! halves that and matches what DVS USB transports actually ship.
//!
//! Layout (MSB → LSB):
//!
//! ```text
//! | 63 .. 24 : t (40 bits, µs)  | 23 .. 13 : x (11 bits) |
//! | 12 ..  2 : y (11 bits)     | 1        : p           | 0 : reserved |
//! ```
//!
//! 40 timestamp bits cover ~12.7 days at microsecond resolution; 11
//! coordinate bits cover sensors up to 2048×2048 (Prophesee Gen4 HD is
//! 1280×720).

use super::{Event, Polarity};

/// Number of timestamp bits in the packed encoding.
pub const T_BITS: u32 = 40;
/// Number of bits per spatial coordinate.
pub const XY_BITS: u32 = 11;
/// Maximum encodable timestamp (exclusive).
pub const T_MAX: u64 = 1 << T_BITS;
/// Maximum encodable coordinate (exclusive).
pub const XY_MAX: u16 = 1 << XY_BITS;

const X_SHIFT: u32 = 13;
const Y_SHIFT: u32 = 2;
const P_SHIFT: u32 = 1;
const T_SHIFT: u32 = 24;

/// Pack an event into the 64-bit wire word.
///
/// # Panics
/// In debug builds, panics if `t ≥ 2^40` or a coordinate ≥ 2^11; release
/// builds truncate (masked), matching hardware behaviour.
#[inline]
pub fn pack(ev: &Event) -> u64 {
    debug_assert!(ev.t < T_MAX, "timestamp overflows 40-bit packed field");
    debug_assert!(ev.x < XY_MAX && ev.y < XY_MAX, "coordinate overflows 11-bit field");
    ((ev.t & (T_MAX - 1)) << T_SHIFT)
        | (((ev.x as u64) & (XY_MAX as u64 - 1)) << X_SHIFT)
        | (((ev.y as u64) & (XY_MAX as u64 - 1)) << Y_SHIFT)
        | ((ev.p.is_on() as u64) << P_SHIFT)
}

/// Unpack a 64-bit wire word into an event.
#[inline]
pub fn unpack(word: u64) -> Event {
    Event {
        t: word >> T_SHIFT,
        x: ((word >> X_SHIFT) & (XY_MAX as u64 - 1)) as u16,
        y: ((word >> Y_SHIFT) & (XY_MAX as u64 - 1)) as u16,
        p: Polarity::from_bool((word >> P_SHIFT) & 1 == 1),
    }
}

/// Pack a slice of events into a freshly allocated word vector.
pub fn pack_slice(events: &[Event]) -> Vec<u64> {
    events.iter().map(pack).collect()
}

/// Unpack a slice of words into a freshly allocated event vector.
pub fn unpack_slice(words: &[u64]) -> Vec<Event> {
    words.iter().map(|&w| unpack(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Event;

    #[test]
    fn roundtrip_basic() {
        let ev = Event::on(345, 259, 123_456_789);
        assert_eq!(unpack(pack(&ev)), ev);
        let ev = Event::off(0, 0, 0);
        assert_eq!(unpack(pack(&ev)), ev);
    }

    #[test]
    fn roundtrip_extremes() {
        let ev = Event::on(XY_MAX - 1, XY_MAX - 1, T_MAX - 1);
        assert_eq!(unpack(pack(&ev)), ev);
    }

    #[test]
    fn roundtrip_slice() {
        let evs: Vec<Event> = (0..1000)
            .map(|i| Event::new((i % 346) as u16, (i % 260) as u16, Polarity::from_bool(i % 3 == 0), i as u64 * 7))
            .collect();
        assert_eq!(unpack_slice(&pack_slice(&evs)), evs);
    }

    #[test]
    fn polarity_bit_is_isolated() {
        let on = pack(&Event::on(5, 6, 7));
        let off = pack(&Event::off(5, 6, 7));
        assert_eq!(on ^ off, 1 << 1);
    }
}
