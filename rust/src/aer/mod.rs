//! Address-event representation (AER) primitives.
//!
//! AER formats individual sensor events as singular atoms of spatial,
//! temporal, and polarity information: 4-tuples `(x, y, p, t)` where
//! `x`/`y` are pixel coordinates, `t` is a microsecond timestamp and `p`
//! is the polarity (direction) of the luminosity change — see §2 of the
//! paper and Lichtsteiner et al. (2008).
//!
//! This module defines the in-memory [`Event`] type used throughout the
//! library, the packed 64-bit wire/RAM encoding ([`packed`]), camera
//! geometry ([`Resolution`]) and the checksum workload used by the
//! Fig. 3 concurrency benchmark ([`checksum`]).

pub mod checksum;
pub mod packed;

use std::fmt;

/// Event polarity: the direction of the luminosity change at a pixel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum Polarity {
    /// Luminosity decreased ("OFF" event).
    Off = 0,
    /// Luminosity increased ("ON" event).
    On = 1,
}

impl Polarity {
    /// Construct from a boolean (`true` ⇒ [`Polarity::On`]).
    #[inline]
    pub fn from_bool(b: bool) -> Self {
        if b {
            Polarity::On
        } else {
            Polarity::Off
        }
    }

    /// `true` iff this is an ON event.
    #[inline]
    pub fn is_on(self) -> bool {
        matches!(self, Polarity::On)
    }

    /// Signed contribution of this polarity: `+1.0` for ON, `-1.0` for OFF.
    #[inline]
    pub fn signum(self) -> f32 {
        match self {
            Polarity::On => 1.0,
            Polarity::Off => -1.0,
        }
    }
}

impl From<bool> for Polarity {
    fn from(b: bool) -> Self {
        Polarity::from_bool(b)
    }
}

/// A single address-event: the atomic unit of the whole library.
///
/// Field order and types follow the AER 4-tuple `(x, y, p, t)` of the
/// paper with a microsecond timestamp, which is the native resolution of
/// the DVS sensors AEStream supports (Inivation DAVIS, Prophesee Gen3/4).
///
/// The struct is 16 bytes and `Copy`; streams of events are `Vec<Event>`
/// or `&[Event]` slices, never boxed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Event {
    /// Timestamp in microseconds since stream start.
    pub t: u64,
    /// Horizontal pixel coordinate (column), `0 ≤ x < width`.
    pub x: u16,
    /// Vertical pixel coordinate (row), `0 ≤ y < height`.
    pub y: u16,
    /// Polarity of the luminosity change.
    pub p: Polarity,
}

impl Event {
    /// Construct a new event.
    #[inline]
    pub fn new(x: u16, y: u16, p: Polarity, t: u64) -> Self {
        Event { t, x, y, p }
    }

    /// Construct an ON event (convenience for tests and generators).
    #[inline]
    pub fn on(x: u16, y: u16, t: u64) -> Self {
        Event::new(x, y, Polarity::On, t)
    }

    /// Construct an OFF event (convenience for tests and generators).
    #[inline]
    pub fn off(x: u16, y: u16, t: u64) -> Self {
        Event::new(x, y, Polarity::Off, t)
    }

    /// Linear pixel index in row-major order for a sensor of `width` columns.
    #[inline]
    pub fn pixel_index(&self, width: u16) -> usize {
        self.y as usize * width as usize + self.x as usize
    }
}

impl fmt::Display for Event {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "({},{},{},{})",
            self.x,
            self.y,
            if self.p.is_on() { 1 } else { 0 },
            self.t
        )
    }
}

/// Sensor geometry: width × height in pixels.
///
/// The paper's use-case recording is 346×260 (DAVIS346); common presets
/// are provided as constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Resolution {
    pub width: u16,
    pub height: u16,
}

impl Resolution {
    /// DAVIS346 (Inivation), the paper's use-case camera: 346×260.
    pub const DAVIS_346: Resolution = Resolution::new(346, 260);
    /// DVS128, the original 128×128 silicon retina.
    pub const DVS_128: Resolution = Resolution::new(128, 128);
    /// Prophesee Gen4 HD: 1280×720.
    pub const PROPHESEE_GEN4: Resolution = Resolution::new(1280, 720);

    /// Construct a resolution.
    pub const fn new(width: u16, height: u16) -> Self {
        Resolution { width, height }
    }

    /// Number of pixels.
    #[inline]
    pub const fn pixels(&self) -> usize {
        self.width as usize * self.height as usize
    }

    /// `true` iff the event's coordinates are inside the sensor array.
    #[inline]
    pub fn contains(&self, ev: &Event) -> bool {
        ev.x < self.width && ev.y < self.height
    }
}

impl fmt::Display for Resolution {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}", self.width, self.height)
    }
}

/// Validate that every event of a slice lies within `res` and that
/// timestamps are monotonically non-decreasing. Returns the index of the
/// first offending event, or `None` if the stream is well-formed.
pub fn validate_stream(events: &[Event], res: Resolution) -> Option<usize> {
    let mut last_t = 0u64;
    for (i, ev) in events.iter().enumerate() {
        if !res.contains(ev) || ev.t < last_t {
            return Some(i);
        }
        last_t = ev.t;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_size_is_16_bytes() {
        // Events are ferried by the hundreds of millions; the memory
        // layout is part of the public contract.
        assert_eq!(std::mem::size_of::<Event>(), 16);
    }

    #[test]
    fn polarity_roundtrip() {
        assert_eq!(Polarity::from_bool(true), Polarity::On);
        assert_eq!(Polarity::from_bool(false), Polarity::Off);
        assert!(Polarity::On.is_on());
        assert!(!Polarity::Off.is_on());
        assert_eq!(Polarity::On.signum(), 1.0);
        assert_eq!(Polarity::Off.signum(), -1.0);
    }

    #[test]
    fn pixel_index_row_major() {
        let ev = Event::on(3, 2, 0);
        assert_eq!(ev.pixel_index(10), 23);
    }

    #[test]
    fn resolution_contains() {
        let res = Resolution::DAVIS_346;
        assert_eq!(res.pixels(), 346 * 260);
        assert!(res.contains(&Event::on(345, 259, 0)));
        assert!(!res.contains(&Event::on(346, 0, 0)));
        assert!(!res.contains(&Event::on(0, 260, 0)));
    }

    #[test]
    fn validate_stream_detects_out_of_bounds_and_time_travel() {
        let res = Resolution::new(4, 4);
        let ok = [Event::on(0, 0, 1), Event::off(3, 3, 2)];
        assert_eq!(validate_stream(&ok, res), None);
        let oob = [Event::on(0, 0, 1), Event::on(4, 0, 2)];
        assert_eq!(validate_stream(&oob, res), Some(1));
        let unsorted = [Event::on(0, 0, 5), Event::on(0, 0, 4)];
        assert_eq!(validate_stream(&unsorted, res), Some(1));
    }

    #[test]
    fn display_formats_as_tuple() {
        assert_eq!(Event::on(1, 2, 3).to_string(), "(1,2,1,3)");
    }
}
