//! The Fig. 3 benchmark workload.
//!
//! "The actual work done in the benchmark is as straight-forward as
//! possible to separate the effect of the synchronization: we simply sum
//! up the coordinates in every event as a form of checksum that is
//! verified against the true checksum at the end of the benchmark."
//! (paper §4.1)
//!
//! [`CoordinateChecksum`] is that workload; it is deliberately trivial
//! (two integer adds per event) so any throughput difference between the
//! [`crate::engine`] implementations is attributable to synchronization,
//! not compute.

use super::Event;

/// Accumulates the sum of `x` and `y` coordinates over a stream.
///
/// Wrapping arithmetic: 90 M events × max-coordinate sums stay far below
/// `u64::MAX`, but wrapping makes the checksum well-defined for any
/// stream length and keeps the hot loop branch-free.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CoordinateChecksum {
    /// Running sum of x + y over all consumed events.
    pub sum: u64,
    /// Number of events consumed.
    pub count: u64,
}

impl CoordinateChecksum {
    /// Fresh, zeroed checksum.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume one event.
    #[inline(always)]
    pub fn push(&mut self, ev: &Event) {
        self.sum = self.sum.wrapping_add(ev.x as u64 + ev.y as u64);
        self.count += 1;
    }

    /// Consume a buffer of events (the threaded engines hand over slices).
    #[inline]
    pub fn push_slice(&mut self, evs: &[Event]) {
        // Manually accumulated in a local so the compiler keeps it in a
        // register across the loop; `push` via &mut self defeats that on
        // some codegen paths.
        let mut s = self.sum;
        for ev in evs {
            s = s.wrapping_add(ev.x as u64 + ev.y as u64);
        }
        self.sum = s;
        self.count += evs.len() as u64;
    }

    /// Merge a partial checksum computed by another worker.
    #[inline]
    pub fn merge(&mut self, other: &CoordinateChecksum) {
        self.sum = self.sum.wrapping_add(other.sum);
        self.count += other.count;
    }
}

/// Ground-truth checksum of a full slice, used to verify every engine's
/// result at the end of each benchmark run.
pub fn reference_checksum(events: &[Event]) -> CoordinateChecksum {
    let mut c = CoordinateChecksum::new();
    c.push_slice(events);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aer::Event;

    #[test]
    fn push_matches_slice() {
        let evs: Vec<Event> = (0..257).map(|i| Event::on(i as u16, (i * 3) as u16, i)).collect();
        let mut a = CoordinateChecksum::new();
        for e in &evs {
            a.push(e);
        }
        let b = reference_checksum(&evs);
        assert_eq!(a, b);
        assert_eq!(a.count, 257);
    }

    #[test]
    fn merge_partials_equals_whole() {
        let evs: Vec<Event> = (0..1000).map(|i| Event::off((i % 346) as u16, (i % 260) as u16, i)).collect();
        let whole = reference_checksum(&evs);
        let mut merged = CoordinateChecksum::new();
        for chunk in evs.chunks(97) {
            merged.merge(&reference_checksum(chunk));
        }
        assert_eq!(merged, whole);
    }

    #[test]
    fn empty_stream_is_zero() {
        assert_eq!(reference_checksum(&[]), CoordinateChecksum::new());
    }
}
