//! SPIF wire codec: packed 32-bit event words.
//!
//! SPIF (SpiNNaker Peripheral Interface) ships *live* events as packed
//! words in UDP datagrams — deliberately without timestamps: the
//! receiving side (a SpiNNaker router, or this library's UDP source)
//! timestamps on arrival. Word layout used here (SPIF's default
//! P_Y_X key layout for a 16-bit X field):
//!
//! ```text
//! | 31: polarity | 30..16: y (15 bits) | 15..0: x (16 bits) |
//! ```
//!
//! Datagrams carry at most [`SPIF_MAX_WORDS`] words so they fit a
//! standard 1500-byte MTU with UDP/IP headers to spare.

use anyhow::{bail, Result};

use crate::aer::{Event, Polarity};

/// Max words per datagram: 1400 bytes of payload / 4.
pub const SPIF_MAX_WORDS: usize = 350;

/// Pack one event into a SPIF word (timestamp is dropped by design).
#[inline]
pub fn pack_word(ev: &Event) -> u32 {
    (u32::from(ev.p.is_on()) << 31) | ((ev.y as u32 & 0x7FFF) << 16) | ev.x as u32
}

/// Unpack a SPIF word, stamping it with `t` (receiver arrival time).
#[inline]
pub fn unpack_word(word: u32, t: u64) -> Event {
    Event {
        t,
        x: (word & 0xFFFF) as u16,
        y: ((word >> 16) & 0x7FFF) as u16,
        p: Polarity::from_bool(word >> 31 == 1),
    }
}

/// Encode a slice of events into one or more UDP-ready datagrams.
pub fn encode_datagrams(events: &[Event]) -> Vec<Vec<u8>> {
    events
        .chunks(SPIF_MAX_WORDS)
        .map(|chunk| {
            let mut dgram = Vec::with_capacity(4 * chunk.len());
            for ev in chunk {
                dgram.extend_from_slice(&pack_word(ev).to_le_bytes());
            }
            dgram
        })
        .collect()
}

/// Decode one received datagram, stamping all events with arrival time
/// `t` (µs since stream start).
pub fn decode_datagram(payload: &[u8], t: u64) -> Result<Vec<Event>> {
    if payload.len() % 4 != 0 {
        bail!("spif: datagram length {} not a multiple of 4", payload.len());
    }
    Ok(payload
        .chunks_exact(4)
        .map(|b| unpack_word(u32::from_le_bytes(b.try_into().unwrap()), t))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn word_roundtrip_preserves_xyp() {
        let events = synthetic_events(1000, 346, 260);
        for ev in &events {
            let back = unpack_word(pack_word(ev), 42);
            assert_eq!((back.x, back.y, back.p), (ev.x, ev.y, ev.p));
            assert_eq!(back.t, 42);
        }
    }

    #[test]
    fn datagrams_respect_mtu() {
        let events = synthetic_events(1000, 346, 260);
        let dgrams = encode_datagrams(&events);
        assert_eq!(dgrams.len(), events.len().div_ceil(SPIF_MAX_WORDS));
        for d in &dgrams {
            assert!(d.len() <= SPIF_MAX_WORDS * 4);
            assert_eq!(d.len() % 4, 0);
        }
        let total: usize = dgrams.iter().map(|d| d.len() / 4).sum();
        assert_eq!(total, events.len());
    }

    #[test]
    fn decode_rejects_ragged_datagram() {
        assert!(decode_datagram(&[1, 2, 3], 0).is_err());
    }

    #[test]
    fn decode_roundtrip_through_datagrams() {
        let events = synthetic_events(777, 640, 480);
        let mut decoded = Vec::new();
        for d in encode_datagrams(&events) {
            decoded.extend(decode_datagram(&d, 7).unwrap());
        }
        assert_eq!(decoded.len(), events.len());
        for (a, b) in decoded.iter().zip(&events) {
            assert_eq!((a.x, a.y, a.p), (b.x, b.y, b.p));
        }
    }

    #[test]
    fn polarity_lives_in_bit_31() {
        let on = pack_word(&Event::on(1, 2, 0));
        let off = pack_word(&Event::off(1, 2, 0));
        assert_eq!(on ^ off, 1 << 31);
    }
}
