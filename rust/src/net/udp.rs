//! UDP source & sink for SPIF event streams.
//!
//! Blocking `std::net::UdpSocket` I/O with short read timeouts: the
//! socket lives on its own OS thread in pipeline deployments and feeds
//! the processing coroutines through [`crate::rt::sync_channel`], so the
//! request path itself stays lock-free.

use std::net::{SocketAddr, ToSocketAddrs, UdpSocket};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::aer::Event;

use super::spif;

/// Sends event streams as SPIF datagrams.
pub struct UdpEventSender {
    socket: UdpSocket,
    target: SocketAddr,
    /// Datagrams sent so far.
    pub datagrams_sent: u64,
    /// Events sent so far.
    pub events_sent: u64,
}

impl UdpEventSender {
    /// Bind an ephemeral local socket aimed at `target`.
    pub fn connect<A: ToSocketAddrs>(target: A) -> Result<Self> {
        let target = target
            .to_socket_addrs()?
            .next()
            .context("udp sender: target did not resolve")?;
        let bind_addr = if target.is_ipv4() { "0.0.0.0:0" } else { "[::]:0" };
        let socket = UdpSocket::bind(bind_addr).context("udp sender: bind")?;
        Ok(UdpEventSender { socket, target, datagrams_sent: 0, events_sent: 0 })
    }

    /// Send a batch of events, fragmenting into MTU-sized datagrams.
    pub fn send(&mut self, events: &[Event]) -> Result<()> {
        for dgram in spif::encode_datagrams(events) {
            self.socket.send_to(&dgram, self.target).context("udp sender: send_to")?;
            self.datagrams_sent += 1;
        }
        self.events_sent += events.len() as u64;
        Ok(())
    }
}

/// Receives SPIF datagrams and stamps events with arrival time.
pub struct UdpEventReceiver {
    socket: UdpSocket,
    start: Instant,
    buf: Box<[u8; 65536]>,
    /// Events received so far.
    pub events_received: u64,
    /// Datagrams received so far.
    pub datagrams_received: u64,
}

impl UdpEventReceiver {
    /// Bind to `addr` (e.g. `"127.0.0.1:3333"`). Arrival timestamps are
    /// microseconds since this call.
    pub fn bind<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let socket = UdpSocket::bind(addr).context("udp receiver: bind")?;
        socket
            .set_read_timeout(Some(Duration::from_millis(20)))
            .context("udp receiver: timeout")?;
        Ok(UdpEventReceiver {
            socket,
            start: Instant::now(),
            buf: Box::new([0u8; 65536]),
            events_received: 0,
            datagrams_received: 0,
        })
    }

    /// The locally bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.socket.local_addr()?)
    }

    /// Bound how long one [`recv_batch`](Self::recv_batch) may block
    /// waiting for a datagram. Callers polling in a loop (the streaming
    /// [`crate::stream::UdpSource`]) size this against their idle
    /// timeout so an idle socket costs a cheap bounded wait per poll
    /// instead of a hot spin.
    pub fn set_poll_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.socket
            .set_read_timeout(Some(timeout.max(Duration::from_micros(100))))
            .context("udp receiver: timeout")?;
        Ok(())
    }

    /// Receive one datagram's worth of events, or `None` on timeout.
    pub fn recv_batch(&mut self) -> Result<Option<Vec<Event>>> {
        match self.socket.recv_from(&mut self.buf[..]) {
            Ok((n, _peer)) => {
                let t = self.start.elapsed().as_micros() as u64;
                let events = spif::decode_datagram(&self.buf[..n], t)?;
                self.datagrams_received += 1;
                self.events_received += events.len() as u64;
                Ok(Some(events))
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                Ok(None)
            }
            Err(e) => Err(e).context("udp receiver: recv_from"),
        }
    }

    /// Drain datagrams until `deadline` or until `max_events` arrived.
    pub fn recv_until(&mut self, deadline: Instant, max_events: usize) -> Result<Vec<Event>> {
        let mut out = Vec::new();
        while Instant::now() < deadline && out.len() < max_events {
            if let Some(batch) = self.recv_batch()? {
                out.extend(batch);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::synthetic_events;

    #[test]
    fn loopback_roundtrip() {
        let mut rx = UdpEventReceiver::bind("127.0.0.1:0").unwrap();
        let addr = rx.local_addr().unwrap();
        let mut tx = UdpEventSender::connect(addr).unwrap();

        let events = synthetic_events(1000, 346, 260);
        tx.send(&events).unwrap();
        assert_eq!(tx.events_sent, 1000);
        assert!(tx.datagrams_sent >= 2);

        let got = rx
            .recv_until(Instant::now() + Duration::from_secs(2), events.len())
            .unwrap();
        // UDP on loopback is effectively reliable & ordered; x/y/p survive,
        // timestamps are re-assigned on arrival.
        assert_eq!(got.len(), events.len());
        for (a, b) in got.iter().zip(&events) {
            assert_eq!((a.x, a.y, a.p), (b.x, b.y, b.p));
        }
        assert_eq!(rx.events_received, 1000);
    }

    #[test]
    fn recv_times_out_quietly() {
        let mut rx = UdpEventReceiver::bind("127.0.0.1:0").unwrap();
        assert!(rx.recv_batch().unwrap().is_none());
    }

    #[test]
    fn empty_send_is_a_noop() {
        let rx = UdpEventReceiver::bind("127.0.0.1:0").unwrap();
        let mut tx = UdpEventSender::connect(rx.local_addr().unwrap()).unwrap();
        tx.send(&[]).unwrap();
        assert_eq!(tx.datagrams_sent, 0);
    }
}
