//! Network transport: the SPIF protocol over UDP.
//!
//! The paper streams events to/from the SpiNNaker neuromorphic platform
//! through the SpiNNaker Peripheral Interface (SPIF), a UDP-based
//! protocol of packed 32-bit event words. [`spif`] implements the wire
//! codec; [`udp`] the socket source/sink used by the CLI and the
//! `spif_stream` example.

pub mod spif;
pub mod udp;

pub use spif::{decode_datagram, encode_datagrams, SPIF_MAX_WORDS};
pub use udp::{UdpEventReceiver, UdpEventSender};
