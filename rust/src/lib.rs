//! # AEStream (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *"AEStream: Accelerated
//! event-based processing with coroutines"* (Pedersen & Conradt, 2022).
//!
//! AEStream streams **address-event representations** (AER) — the
//! `(x, y, p, t)` tuples emitted by event cameras and neuromorphic
//! hardware — from sources (files, UDP/SPIF, synthetic cameras) to sinks
//! (files, UDP, stdout, an XLA/PJRT compute device), using **stackless
//! coroutines** for per-event handoff instead of lock-guarded buffers.
//!
//! ## Layer map
//!
//! * [`aer`] — event types, packed encodings, the checksum workload;
//! * [`formats`] — file codecs (AEDAT 3.1, Prophesee EVT2/EVT3/DAT,
//!   raw, text), each with batch ([`formats::EventCodec`]) and
//!   incremental ([`formats::streaming`]) decode/encode; the packed
//!   formats' per-word decode loops live in one kernel layer
//!   ([`formats::simd`]) with explicit SSE2 (x86_64) and NEON
//!   (aarch64) fast paths behind the `simd` cargo feature and a
//!   property-tested scalar reference;
//! * [`net`] — SPIF wire protocol over UDP;
//! * [`camera`] — synthetic event-camera source;
//! * [`pipeline`] — composable per-event transforms (the paper's
//!   uniform-signature functions), each declaring a
//!   [`pipeline::TransformClass`] (stateless / geometry-keyed stateful
//!   / barrier), frame binning, backpressure, and the deferred
//!   [`pipeline::PipelineSpec`] the CLI parses;
//! * [`stream`] — the `EventSource` → stages → `EventSink` trait layer
//!   and its incremental drivers (coroutine + sync): O(chunk) memory
//!   for endless streams; batches travel as refcounted immutable
//!   [`stream::EventChunk`] range views, so broadcast/stripe routing
//!   and delivery are refcount bumps, with per-node
//!   `bytes_moved`/`chunks_cloned` copy-traffic counters surfaced in
//!   `StreamReport` and `--report-json`; batch buffers recycle through
//!   the sole-owner [`stream::ChunkPool`] (`pool_hits`/`pool_misses`
//!   metered alongside the copy counters);
//! * [`stream::codec_plane`] — the shared codec worker plane: a
//!   fixed-size decode pool (`--decode-threads`) that ingest paths
//!   hand raw byte buffers to instead of decoding inline; splittable
//!   formats (raw/AEDAT2/DAT per-word, EVT2 at `TIME_HIGH` boundaries
//!   via a vectorized pre-scan) decode in parallel, sequential ones
//!   pipeline through a checked-out decoder, and sequence-keyed
//!   reassembly restores per-stream order — byte-identical to inline
//!   decode, with worker/queue/reassembly counters in `StreamReport`;
//! * [`stream::merge`] — the shared k-way merge core: a loser tree
//!   selects the next lane in O(log k) and emits whole *runs*
//!   (galloped via `partition_point`) as zero-copy views of the
//!   producer's buffer, with the old linear scan retained as the
//!   property-tested equivalence oracle;
//! * [`stream::stage`] — pipeline stages as first-class topology
//!   nodes: shardable stages run as N stripe-shard workers (inline or
//!   one OS thread each) with halo ghost events and a sequence-keyed
//!   re-merge, byte-identical to the serial pipeline;
//! * [`stream::topology`] — fan-in/fan-out graphs over that layer:
//!   N sources merged in timestamp order through the bulk merge core
//!   (optionally one OS thread per source over the lock-free ring;
//!   idle live sources heartbeat after a bounded grace instead of
//!   stalling the merge; a single active lane streams zero-copy run
//!   views), one shared stage chain, M routed sinks (optionally one
//!   pump thread per sink), with per-node counters in `StreamReport`;
//! * [`stream::graph`] — declarative topology graphs: a
//!   [`stream::GraphSpec`] of named source/merge/stage/router/sink
//!   nodes with explicit edges, built via [`stream::Topology`]'s
//!   fluent builder, validated (acyclicity, geometry propagation,
//!   readable errors) and compiled onto the same driver — per-branch
//!   stage chains into independent sinks, per-node thread placement;
//!   the legacy fixed shape and the CLI clause syntax are lowerings;
//! * [`stream::buffer`] — durable spill-to-disk edge buffers: a
//!   crash-safe segmented journal (append-only segments of
//!   length-prefixed, CRC32-framed record batches; torn tails detected
//!   and truncated on reopen) behind every `sink_buffered` edge, with
//!   a bounded in-memory front that spills when the sink lags and
//!   drains FIFO byte-identically to a pure-memory edge; journals
//!   replay through [`stream::ReplaySource`] (`input replay <dir>`,
//!   `--from-offset`, `--speed orig|max`) with a persisted acked
//!   offset for at-least-once resume, and
//!   `buffer_*` gauges surface in `StreamReport`/`--report-json`;
//! * [`stream::adapt`] — the adaptive runtime: controllers sample the
//!   live telemetry plane ([`metrics::LiveNode`]) every N batches and
//!   re-cut shard stripe boundaries / re-tune the chunk size at epoch
//!   barriers, output byte-identical to serial across re-cuts; custom
//!   controllers register by name ([`stream::adapt::registry`]) and
//!   resolve from `--adaptive` lists end to end;
//! * [`serve`] — the network serving plane: `tcp-listen` / `http-listen`
//!   sources that admit many concurrent clients at runtime (each a
//!   dynamically attached merge lane behind an AIMD-tuned credit
//!   window, so memory stays bounded by `clients × window`), and the
//!   `subscribe` sink fanning deliveries out to N TCP consumers with
//!   slow-consumer eviction;
//! * [`engine`] — the Fig. 3 concurrency contenders (sync / threads /
//!   coroutines / lock-free ring);
//! * [`rt`] — the hand-rolled cooperative async runtime (coroutines);
//! * [`sync`] — lock-free SPSC ring (head/tail on separate cache lines
//!   to kill false sharing between producer and consumer);
//! * [`runtime`] — XLA/PJRT device runtime with host→device transfer
//!   accounting (the paper's GPU stand-in);
//! * [`snn`] — pure-Rust LIF + convolution reference edge detector;
//! * [`coordinator`] — the four-scenario Fig. 4 use-case runner and the
//!   CLI's free `input → filters → output` composition over [`stream`];
//! * [`metrics`] — counters, rate meters, timing histograms, and the
//!   live telemetry plane (`LiveNode`);
//! * [`bench`] — statistics harness used by `benches/` (no criterion
//!   offline);
//! * [`testutil`] — deterministic RNG, generators, mini property harness.

pub mod aer;
pub mod bench;
pub mod camera;
pub mod cli;
pub mod control;
pub mod coordinator;
pub mod engine;
pub mod formats;
pub mod metrics;
pub mod net;
pub mod pipeline;
pub mod rt;
pub mod runtime;
pub mod serve;
pub mod snn;
pub mod stream;
pub mod sync;
pub mod testutil;
