//! Sharded stage-graph acceptance: for **every registered op**, the
//! stripes-shard → stage → re-merge path is event-for-event identical
//! (order, payload, counters) to the serial `Pipeline` across chunk
//! sizes 1–7 and shard counts 1–4, and per-stage `NodeReport` counters
//! sum to the edge totals.

use anyhow::Result;

use aestream::aer::{Event, Resolution};
use aestream::pipeline::{registry, PipelineSpec, StageSpec, TransformClass};
use aestream::stream::{
    run_topology, BatchProcessor, EventSink, MemorySource, Reconfigure, SinkSummary,
    StageGraph, StageOptions, StreamDriver, TopologyConfig,
};
use aestream::testutil::prop::check;
use aestream::testutil::{synthetic_events_seeded, SplitMix64};

/// Sink that records every delivered event, in order.
#[derive(Default)]
struct CollectSink {
    events: Vec<Event>,
}

impl EventSink for CollectSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        self.events.extend_from_slice(batch);
        Ok(())
    }
    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }
    fn describe(&self) -> String {
        "collect".into()
    }
}

/// Random individually-time-ordered stream on a random small geometry.
fn gen_stream(rng: &mut SplitMix64) -> (Vec<Event>, Resolution) {
    let width = 8 + (rng.next_u64() % 56) as u16;
    let height = 8 + (rng.next_u64() % 56) as u16;
    let n = (rng.next_u64() % 400) as usize;
    let mut t = 0u64;
    let events = (0..n)
        .map(|_| {
            t += rng.next_u64() % 5;
            Event {
                t,
                x: (rng.next_u64() % width as u64) as u16,
                y: (rng.next_u64() % height as u64) as u16,
                p: aestream::aer::Polarity::from_bool(rng.next_u64() & 1 == 1),
            }
        })
        .collect();
    (events, Resolution::new(width, height))
}

/// Drive `spec` over `events` through a compiled graph, chunked.
fn run_graph(
    spec: &PipelineSpec,
    events: &[Event],
    res: Resolution,
    chunk: usize,
    opts: &StageOptions,
) -> (Vec<Event>, Vec<aestream::metrics::NodeReport>) {
    let mut graph = StageGraph::compile(spec, res, opts);
    let mut out = Vec::new();
    for batch in events.chunks(chunk) {
        out.extend(graph.process_batch(batch).unwrap());
    }
    graph.finish_stages().unwrap();
    let reports = graph.stage_reports();
    (out, reports)
}

/// The tentpole acceptance property: every registered op, chunk sizes
/// 1–7, shard counts 1–4, inline shard workers — sharded ≡ serial.
#[test]
fn prop_every_registered_op_shards_identically() {
    let ops = registry::transform_ops();
    for op in &ops {
        check(
            &format!("sharded ≡ serial for op {}", op.name),
            24,
            |rng| {
                let (events, res) = gen_stream(rng);
                let chunk = 1 + (rng.next_u64() as usize) % 7;
                let shards = 1 + (rng.next_u64() as usize) % 4;
                (events, res, chunk, shards)
            },
            |(events, res, chunk, shards)| {
                let spec = PipelineSpec::new().then((op.example)());
                let expected = spec.build_pipeline(*res).process(events);
                let opts = StageOptions { shards: *shards, shard_threads: false };
                let (got, reports) = run_graph(&spec, events, *res, *chunk, &opts);
                // Counters: stage input = every event fed; output chain.
                let counters_ok = reports.len() == 1
                    && reports[0].events == events.len() as u64
                    && reports[0].events - reports[0].dropped == got.len() as u64
                    && (reports[0].shard_events.is_empty()
                        || reports[0].shard_events.iter().sum::<u64>() == reports[0].events);
                got == expected && counters_ok
            },
        );
    }
}

/// A random valid stripe cut: `m` ascending bounds ending at `width`,
/// every stripe at least `min_w` wide. `None` when the canvas cannot
/// fit one.
fn random_bounds(rng: &mut SplitMix64, width: u16, m: usize, min_w: u16) -> Option<Vec<u16>> {
    let need = m * min_w as usize;
    if (width as usize) < need || m < 2 {
        return None;
    }
    let slack = width as usize - need;
    let mut cuts: Vec<usize> =
        (0..m - 1).map(|_| (rng.next_u64() as usize) % (slack + 1)).collect();
    cuts.sort_unstable();
    let mut bounds: Vec<u16> = cuts
        .iter()
        .enumerate()
        .map(|(k, &c)| ((k + 1) * min_w as usize + c) as u16)
        .collect();
    bounds.push(width);
    Some(bounds)
}

/// Adaptive-runtime acceptance: for **every registered op**, forcing a
/// stripe re-cut after every epoch (epochs of 1–3 batches, shards 1–4,
/// chunks 1–7) leaves the sharded output byte-identical to the serial
/// pipeline — per-column state demonstrably survives arbitrary
/// ownership moves via export_rows/import_rows.
#[test]
fn prop_every_registered_op_survives_forced_recuts() {
    let ops = registry::transform_ops();
    for op in &ops {
        check(
            &format!("re-cut sharded ≡ serial for op {}", op.name),
            16,
            |rng| {
                let (events, res) = gen_stream(rng);
                let chunk = 1 + (rng.next_u64() as usize) % 7;
                let shards = 1 + (rng.next_u64() as usize) % 4;
                let epoch = 1 + (rng.next_u64() as usize) % 3;
                let seed = rng.next_u64();
                (events, res, chunk, shards, epoch, seed)
            },
            |(events, res, chunk, shards, epoch, seed)| {
                let spec = PipelineSpec::new().then((op.example)());
                let expected = spec.build_pipeline(*res).process(events);
                let opts = StageOptions { shards: *shards, shard_threads: false };
                let mut graph = StageGraph::compile(&spec, *res, &opts);
                let m = graph.node_shards(0);
                let min_w = op.class.halo().max(1);
                let mut rng = SplitMix64::new(*seed);
                let mut got = Vec::new();
                for (i, batch) in events.chunks(*chunk).enumerate() {
                    got.extend(graph.process_batch(batch).unwrap());
                    if m > 1 && (i + 1) % epoch == 0 {
                        if let Some(bounds) = random_bounds(&mut rng, res.width, m, min_w)
                        {
                            graph
                                .reconfigure(&Reconfigure::RecutStripes {
                                    stage: 0,
                                    bounds,
                                })
                                .unwrap();
                        }
                    }
                }
                graph.finish_stages().unwrap();
                got == expected
            },
        );
    }
}

/// Same property through OS-thread shard workers (fewer cases — thread
/// spawn per case), including the class that needs halo ghosts.
#[test]
fn prop_threaded_shards_match_serial() {
    for name in ["denoise", "refractory", "flip-x"] {
        let op = registry::transform_ops()
            .into_iter()
            .find(|op| op.name == name)
            .expect("registered op");
        check(
            &format!("threaded sharded ≡ serial for op {name}"),
            6,
            |rng| {
                let (events, res) = gen_stream(rng);
                let chunk = 1 + (rng.next_u64() as usize) % 7;
                let shards = 2 + (rng.next_u64() as usize) % 3;
                (events, res, chunk, shards)
            },
            |(events, res, chunk, shards)| {
                let spec = PipelineSpec::new().then((op.example)());
                let expected = spec.build_pipeline(*res).process(events);
                let opts = StageOptions { shards: *shards, shard_threads: true };
                let (got, _) = run_graph(&spec, events, *res, *chunk, &opts);
                got == expected
            },
        );
    }
}

/// Multi-stage chains: stages re-route on their *own* input
/// coordinates, so coordinate-moving stages (flip, downsample,
/// transpose) compose safely with geometry-keyed state downstream.
#[test]
fn prop_full_registered_chain_shards_identically() {
    check(
        "sharded ≡ serial for the full registered op chain",
        24,
        |rng| {
            let (events, res) = gen_stream(rng);
            let chunk = 1 + (rng.next_u64() as usize) % 7;
            let shards = 1 + (rng.next_u64() as usize) % 4;
            (events, res, chunk, shards)
        },
        |(events, res, chunk, shards)| {
            let mut spec = PipelineSpec::new();
            for op in registry::transform_ops() {
                if op.name == "polarity" || op.name == "crop" {
                    // Keep enough traffic flowing to exercise state.
                    continue;
                }
                spec.push((op.example)());
            }
            let expected = spec.build_pipeline(*res).process(events);
            let opts = StageOptions { shards: *shards, shard_threads: false };
            let (got, reports) = run_graph(&spec, events, *res, *chunk, &opts);
            // The chaining invariant: stage n+1 input = stage n output.
            let chain_ok = reports.windows(2).all(|w| w[1].events == w[0].events - w[0].dropped)
                && reports.first().map(|r| r.events) == Some(events.len() as u64);
            got == expected && chain_ok
        },
    );
}

/// Full-topology acceptance: 2 fused sources → sharded stateful stage →
/// collect sink, per-stage NodeReports summing to the edge totals, for
/// both drivers.
#[test]
fn topology_stage_reports_sum_to_edge_totals() {
    let res = Resolution::new(64, 64);
    let a = synthetic_events_seeded(5000, 64, 64, 31);
    let b = synthetic_events_seeded(5000, 64, 64, 32);
    let canvas = Resolution::new(128, 64);

    for driver in [StreamDriver::Coroutine { channel_capacity: 1 }, StreamDriver::Sync] {
        let spec = PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| {
                aestream::pipeline::ops::RefractoryFilter::new(res, 50)
            }))
            .then(StageSpec::new(|res: Resolution| {
                aestream::pipeline::ops::BackgroundActivityFilter::new(res, 1000)
            }));
        assert_eq!(spec.stages()[1].class(), TransformClass::Stateful { halo: 1 });
        let mut graph =
            StageGraph::compile(&spec, canvas, &StageOptions { shards: 4, shard_threads: false });
        let sources =
            vec![MemorySource::new(a.clone(), res, 256), MemorySource::new(b.clone(), res, 256)];
        let config = TopologyConfig { chunk_size: 256, driver, ..Default::default() };
        let report = run_topology(
            sources,
            &mut graph,
            vec![CollectSink::default()],
            None,
            &config,
        )
        .unwrap();

        assert_eq!(report.events_in, 10_000);
        assert_eq!(report.stages.len(), 2, "{driver:?}");
        // Edge total in = first stage in.
        assert_eq!(report.stages[0].events, report.events_in, "{driver:?}");
        // Chain: stage n+1 in = stage n out.
        assert_eq!(
            report.stages[1].events,
            report.stages[0].events - report.stages[0].dropped,
            "{driver:?}"
        );
        // Last stage out = edge total out.
        assert_eq!(
            report.stages[1].events - report.stages[1].dropped,
            report.events_out,
            "{driver:?}"
        );
        // Shard traffic sums to stage traffic.
        for stage in &report.stages {
            if !stage.shard_events.is_empty() {
                assert_eq!(
                    stage.shard_events.iter().sum::<u64>(),
                    stage.events,
                    "{driver:?}"
                );
            }
        }

        // And the whole sharded edge matches the serial reference:
        // batch-fuse the sources, then run the serial pipeline.
        let layout = aestream::pipeline::fusion::SourceLayout::side_by_side(&[res, res]);
        let (fused, _) = aestream::pipeline::fusion::fuse(&[&a, &b], &layout);
        let expected = spec.build_pipeline(canvas).process(&fused);
        assert_eq!(report.events_out, expected.len() as u64, "{driver:?}");
    }
}

/// Sharding a stage through the whole topology driver returns the exact
/// serial event stream (payloads included), threaded shards included.
#[test]
fn topology_sharded_output_is_byte_identical() {
    let res = Resolution::new(90, 60);
    let events = synthetic_events_seeded(20_000, 90, 60, 77);
    let spec = PipelineSpec::new().then(StageSpec::new(|res: Resolution| {
        aestream::pipeline::ops::BackgroundActivityFilter::new(res, 500)
    }));
    let expected = spec.build_pipeline(res).process(&events);

    for shard_threads in [false, true] {
        let mut graph = StageGraph::compile(
            &spec,
            res,
            &StageOptions { shards: 3, shard_threads },
        );
        let config = TopologyConfig { chunk_size: 512, ..Default::default() };
        let mut sink = CollectSink::default();
        run_topology(
            vec![MemorySource::new(events.clone(), res, 512)],
            &mut graph,
            vec![&mut sink],
            None,
            &config,
        )
        .unwrap();
        assert_eq!(sink.events, expected, "shard_threads={shard_threads}");
    }
}
