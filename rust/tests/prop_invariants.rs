//! Property-based invariant suites (mini-harness in `testutil::prop`).
//!
//! Each property generates randomized event streams / parameters and
//! asserts a library-wide invariant: codecs are lossless, engines are
//! equivalent, framing conserves events, filters respect their specs.

use aestream::aer::checksum::reference_checksum;
use aestream::aer::{packed, validate_stream, Event, Polarity, Resolution};
use aestream::engine::EngineKind;
use aestream::formats::{EventCodec, Format};
use aestream::net::spif;
use aestream::pipeline::framer::Framer;
use aestream::pipeline::ops;
use aestream::pipeline::{EventTransform, Pipeline};
#[allow(unused_imports)]
use aestream::pipeline::framer::Frame;
use aestream::testutil::prop::{check, check_vec};
use aestream::testutil::SplitMix64;

/// Random well-formed event stream: sorted timestamps, in-bounds coords.
fn gen_stream(rng: &mut SplitMix64, max_len: usize, res: Resolution) -> Vec<Event> {
    let len = rng.next_below(max_len as u64 + 1) as usize;
    let mut t = 0u64;
    (0..len)
        .map(|_| {
            t += rng.next_below(50);
            Event {
                t,
                x: rng.next_below(res.width as u64) as u16,
                y: rng.next_below(res.height as u64) as u16,
                p: Polarity::from_bool(rng.next_below(2) == 1),
            }
        })
        .collect()
}

const RES: Resolution = Resolution::DAVIS_346;

#[test]
fn prop_all_codecs_roundtrip_losslessly() {
    for format in Format::ALL {
        check_vec(
            &format!("codec {format} roundtrip"),
            24,
            |rng| gen_stream(rng, 600, RES),
            |events| {
                let codec = format.codec();
                let mut buf = Vec::new();
                codec.encode(events, RES, &mut buf).unwrap();
                match codec.decode(&mut &buf[..]) {
                    Ok((decoded, res)) => decoded == events && res == RES,
                    Err(_) => false,
                }
            },
        );
    }
}

#[test]
fn prop_packed_encoding_is_bijective() {
    check_vec(
        "packed 64-bit roundtrip",
        48,
        |rng| gen_stream(rng, 400, RES),
        |events| packed::unpack_slice(&packed::pack_slice(events)) == *events,
    );
}

#[test]
fn prop_all_engines_agree_with_sync() {
    check_vec(
        "engine equivalence",
        12,
        |rng| gen_stream(rng, 3000, RES),
        |events| {
            let expected = reference_checksum(events);
            [
                EngineKind::Threaded { buffer_size: 64, workers: 2 },
                EngineKind::Threaded { buffer_size: 1024, workers: 4 },
                EngineKind::Coro,
                EngineKind::CoroChannel { channel_capacity: 1 },
                EngineKind::CoroChannel { channel_capacity: 128 },
                EngineKind::Spsc { ring_capacity: 256 },
            ]
            .into_iter()
            .all(|kind| kind.run_checksum(events) == expected)
        },
    );
}

#[test]
fn prop_framer_conserves_events_and_windows_nest() {
    check(
        "framer conservation",
        24,
        |rng| {
            let events = gen_stream(rng, 2000, RES);
            let window = 1 + rng.next_below(5000);
            (events, window)
        },
        |(events, window)| {
            let frames = Framer::frames_of(RES, *window, events);
            let total: u64 = frames.iter().map(|f| f.event_count).sum();
            let windows_ok = frames.iter().all(|f| {
                f.t_end - f.t_start == *window && f.t_start % *window == 0
            });
            // Frames must be in increasing window order.
            let ordered = frames.windows(2).all(|w| w[0].t_start < w[1].t_start);
            total == events.len() as u64 && windows_ok && ordered
        },
    );
}

#[test]
fn prop_spif_words_preserve_xyp() {
    check_vec(
        "spif word roundtrip",
        48,
        |rng| gen_stream(rng, 400, Resolution::PROPHESEE_GEN4),
        |events| {
            let mut out = Vec::new();
            for d in spif::encode_datagrams(events) {
                out.extend(spif::decode_datagram(&d, 0).unwrap());
            }
            out.len() == events.len()
                && out
                    .iter()
                    .zip(events)
                    .all(|(a, b)| (a.x, a.y, a.p) == (b.x, b.y, b.p))
        },
    );
}

#[test]
fn prop_refractory_output_respects_period() {
    check(
        "refractory spacing",
        24,
        |rng| {
            let events = gen_stream(rng, 1500, RES);
            let period = 1 + rng.next_below(2000);
            (events, period)
        },
        |(events, period)| {
            let mut last: std::collections::HashMap<(u16, u16), u64> = Default::default();
            let mut f = ops::RefractoryFilter::new(RES, *period);
            events.iter().all(|ev| match f.apply(*ev) {
                Some(out) => {
                    let ok = match last.get(&(out.x, out.y)) {
                        Some(&prev) => out.t >= prev + *period,
                        None => true,
                    };
                    last.insert((out.x, out.y), out.t);
                    ok
                }
                None => true,
            })
        },
    );
}


#[test]
fn prop_crop_then_bounds() {
    check_vec(
        "crop bounds + re-origin",
        32,
        |rng| gen_stream(rng, 800, RES),
        |events| {
            let mut crop = ops::RoiCrop::new(40, 30, 100, 80);
            events.iter().all(|ev| match crop.apply(*ev) {
                Some(out) => out.x < 100 && out.y < 80,
                None => {
                    !(ev.x >= 40 && ev.x < 140 && ev.y >= 30 && ev.y < 110)
                }
            })
        },
    );
}

#[test]
fn prop_pipeline_output_is_subset_in_order() {
    check_vec(
        "pipeline subset/order",
        24,
        |rng| gen_stream(rng, 800, RES),
        |events| {
            let mut p = Pipeline::new()
                .then(ops::PolarityFilter::keep(Polarity::On))
                .then(ops::Downsample::new(2))
                .then(ops::RoiCrop::new(0, 0, 80, 80));
            let out = p.process(events);
            // Timestamps must be a subsequence of the input's.
            let mut it = events.iter();
            out.iter().all(|o| it.any(|e| e.t == o.t))
        },
    );
}

#[test]
fn prop_generated_streams_are_valid() {
    check_vec(
        "generator sanity",
        24,
        |rng| gen_stream(rng, 1000, RES),
        |events| validate_stream(events, RES).is_none(),
    );
}
