//! Cross-format streaming round-trips: every codec written through the
//! chunked `EventSink` and re-read through the chunked `EventSource`
//! must reproduce the stream exactly — including chunk boundaries that
//! split packed words, packet headers, and CSV lines.

use aestream::aer::{Event, Resolution};
use aestream::formats::{self, EventCodec, Format};
use aestream::pipeline::Pipeline;
use aestream::stream::{self, EventSink, EventSource, FileSink, FileSource, StreamConfig};
use aestream::testutil::{synthetic_events, synthetic_events_seeded};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("aestream-sf-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn drain(source: &mut FileSource) -> Vec<Event> {
    let mut out = Vec::new();
    while let Some(batch) = source.next_batch().unwrap() {
        out.extend(batch);
    }
    out
}

#[test]
fn every_format_roundtrips_through_streaming_sink_and_source() {
    let dir = tmpdir("rt");
    let events = synthetic_events(4000, 346, 260);
    let res = Resolution::DAVIS_346;
    for format in Format::ALL {
        let path = dir.join(format!("stream.{}", format.codec().name()));
        // Write in deliberately odd batch sizes.
        let mut sink = FileSink::create(&path, format, res).unwrap();
        for batch in events.chunks(613) {
            sink.consume(batch).unwrap();
        }
        sink.finish().unwrap();

        // The batch reader must accept the streamed file…
        let (decoded, dres, detected) = formats::read_events_auto(&path).unwrap();
        assert_eq!(decoded, events, "{format} (batch read-back)");
        assert_eq!(dres, res, "{format} geometry");
        assert_eq!(detected, format, "{format} sniffing");

        // …and so must the chunked reader, at several chunk sizes that
        // misalign with every record/packet/word size.
        for chunk in [37usize, 1000, 8192] {
            let mut source = FileSource::open(&path, chunk).unwrap();
            assert_eq!(source.format(), format, "{format} chunk={chunk}");
            let streamed = drain(&mut source);
            assert_eq!(streamed, events, "{format} chunk={chunk}");
            assert_eq!(source.resolution(), res, "{format} chunk={chunk} geometry");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_conversion_matrix_is_lossless() {
    // raw → every other format → raw, all through the streaming layer.
    let dir = tmpdir("conv");
    let events = synthetic_events_seeded(2000, 640, 480, 0xC0FFEE);
    let res = Resolution::new(640, 480);
    let origin = dir.join("origin.aeraw");
    let mut sink = FileSink::create(&origin, Format::Raw, res).unwrap();
    sink.consume(&events).unwrap();
    sink.finish().unwrap();

    for format in Format::ALL {
        let via = dir.join(format!("via.{}", format.codec().name()));
        let report = stream::run(
            &mut FileSource::open(&origin, 256).unwrap(),
            &mut Pipeline::new(),
            &mut FileSink::create(&via, format, res).unwrap(),
            StreamConfig::default(),
        )
        .unwrap();
        assert_eq!(report.events_in, events.len() as u64, "{format}");
        assert_eq!(report.events_out, events.len() as u64, "{format}");

        let mut back = FileSource::open(&via, 999).unwrap();
        assert_eq!(drain(&mut back), events, "{format} conversion");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streamed_files_match_batch_written_files_event_for_event() {
    // A file written by the batch `write_events` read through the
    // streaming source (and vice versa) yields identical events.
    let dir = tmpdir("xcheck");
    let events = synthetic_events(1500, 128, 128);
    let res = Resolution::DVS_128;
    for format in Format::ALL {
        let batch_path = dir.join(format!("batch.{}", format.codec().name()));
        formats::write_events(&batch_path, &events, res, format).unwrap();
        let mut source = FileSource::open(&batch_path, 100).unwrap();
        assert_eq!(drain(&mut source), events, "{format}: batch-written, stream-read");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// SIMD-vs-scalar equivalence fuzz: the dispatching word decoders in
/// `formats::simd` must be word-for-word identical to their scalar
/// reference loops no matter where the stream is split. Every piece
/// size below breaks the body at word multiples that land the SSE2
/// blocks (4×u32 for EVT2, 8×u16 for EVT3) across piece boundaries,
/// forcing the dispatcher to re-enter mid-run with carried decoder
/// state. Compiled in every configuration; built with `--features simd`
/// this is the SIMD equivalence gate, and on the default build it pins
/// the dispatcher to the reference semantics.
#[test]
fn word_decoders_match_scalar_reference_at_every_split() {
    use aestream::formats::simd;

    /// Skip the `%`-comment header lines of a Prophesee-style file.
    fn percent_body(bytes: &[u8]) -> &[u8] {
        let mut off = 0;
        while off < bytes.len() && bytes[off] == b'%' {
            off += bytes[off..].iter().position(|&b| b == b'\n').unwrap() + 1;
        }
        &bytes[off..]
    }

    let events = synthetic_events_seeded(5000, 640, 480, 0x51D2);
    let res = Resolution::new(640, 480);

    // EVT2: 4-byte words, SSE2 classifies 4-word blocks.
    let mut enc = Vec::new();
    Format::Evt2.codec().encode(&events, res, &mut enc).unwrap();
    let body = percent_body(&enc);
    let mut want = Vec::new();
    let mut want_th = None;
    simd::decode_evt2_words_scalar(body, &mut want_th, &mut want).unwrap();
    for words in [1usize, 2, 3, 5, 7, 61] {
        let (mut got, mut th) = (Vec::new(), None);
        for piece in body.chunks(words * 4) {
            simd::decode_evt2_words(piece, &mut th, &mut got).unwrap();
        }
        assert_eq!(got, want, "evt2 split into {words}-word pieces");
        assert_eq!(th, want_th, "evt2 carried TIME_HIGH, {words}-word pieces");
    }

    // EVT3: 2-byte words, SSE2 classifies 8-word ADDR_X runs.
    let mut enc = Vec::new();
    Format::Evt3.codec().encode(&events, res, &mut enc).unwrap();
    let body = percent_body(&enc);
    let mut want = Vec::new();
    let mut want_state = simd::Evt3State::default();
    simd::decode_evt3_words_scalar(body, &mut want_state, &mut want).unwrap();
    for words in [1usize, 3, 5, 7, 9, 127] {
        let (mut got, mut state) = (Vec::new(), simd::Evt3State::default());
        for piece in body.chunks(words * 2) {
            simd::decode_evt3_words(piece, &mut state, &mut got).unwrap();
        }
        assert_eq!(got, want, "evt3 split into {words}-word pieces");
    }

    // Raw: 8-byte packed words behind a fixed 16-byte header; the
    // dispatcher is the unrolled autovectorized loop on every target.
    let mut enc = Vec::new();
    Format::Raw.codec().encode(&events, res, &mut enc).unwrap();
    let body = &enc[16..];
    let mut want = Vec::new();
    simd::decode_raw_words_scalar(body, &mut want);
    assert_eq!(want, events, "raw scalar decode is the identity");
    for words in [1usize, 2, 3, 5, 129] {
        let mut got = Vec::new();
        for piece in body.chunks(words * 8) {
            simd::decode_raw_words(piece, &mut got);
        }
        assert_eq!(got, want, "raw split into {words}-word pieces");
    }
}

#[test]
fn empty_streams_roundtrip() {
    let dir = tmpdir("empty");
    for format in Format::ALL {
        let path = dir.join(format!("empty.{}", format.codec().name()));
        let mut sink = FileSink::create(&path, format, Resolution::new(64, 64)).unwrap();
        sink.finish().unwrap();
        let mut source = FileSource::open(&path, 64).unwrap();
        assert!(drain(&mut source).is_empty(), "{format} produced phantom events");
    }
    std::fs::remove_dir_all(&dir).ok();
}
