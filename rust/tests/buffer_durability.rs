//! Durable edge-buffer acceptance (ROADMAP item 2's durability slice):
//!
//! * **Crash-safety property**: a journal truncated at *every* byte
//!   offset recovers exactly the committed-frame prefix — no panic, no
//!   phantom events, and `SegmentWriter` recovery agrees byte-for-byte
//!   with what `ReplaySource` re-serves.
//! * **Kill mid-spill**: a journal torn mid-frame (crashed writer)
//!   reopens to the committed prefix and replays it byte-identically.
//! * **Bounded-memory spill**: a slow sink behind a `disk{cap}` edge
//!   loses nothing, stays byte-identical to the pure-memory edge, and
//!   holds the in-memory front at `front_batches` while spilling.
//! * **Replay-from-offset**: the recorded edge re-serves from offset 0
//!   and from mid-stream (including mid-frame offsets).
//! * **Thread budget**: each buffered edge costs exactly one `buf:w/…`
//!   and one `buf:r/…` thread, both reaped at `finish()`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use aestream::aer::{Event, Resolution};
use aestream::stream::buffer::segment::{SegmentWriter, FRAME_HEADER_BYTES, RECORD_BYTES};
use aestream::stream::{
    read_acked_offset, CaptureSink, DiskBufferConfig, DiskBufferedSink, EventSink, EventSource,
    GraphConfig, MemorySource, ReplaySource, ReplaySpeed, SinkSummary, Topology,
};
use aestream::testutil::synthetic_events_seeded;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "aestream-bufdur-{}-{tag}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Drain a replay source to completion through the `EventSource` API.
fn drain(mut src: ReplaySource) -> Vec<Event> {
    let mut out = Vec::new();
    while let Some(batch) = src.next_batch().unwrap() {
        out.extend_from_slice(&batch);
    }
    out
}

/// The journal's segment files, sorted by index.
fn segment_files(dir: &std::path::Path) -> Vec<PathBuf> {
    let mut segs: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("segment-"))
        })
        .collect();
    segs.sort();
    segs
}

/// Truncate-at-every-byte-offset property: for each cut point the
/// reader yields exactly the frames wholly before the cut (truncation
/// never corrupts a complete frame's CRC, so committed = complete),
/// and writer recovery truncates to the same boundary.
#[test]
fn truncation_at_every_byte_offset_recovers_exactly_the_committed_prefix() {
    const FRAMES: usize = 6;
    const PER_FRAME: usize = 17;
    let events = synthetic_events_seeded(FRAMES * PER_FRAME, 64, 64, 0xD15C);

    let master = tmp_dir("truncate-master");
    {
        let (mut writer, recovery) = SegmentWriter::open(&master, u64::MAX, false).unwrap();
        assert_eq!(recovery.committed_records, 0, "fresh dir recovers nothing");
        for frame in events.chunks(PER_FRAME) {
            writer.append(frame).unwrap();
        }
        writer.sync().unwrap();
    }
    let segs = segment_files(&master);
    assert_eq!(segs.len(), 1, "unbounded target keeps one segment");
    let seg_name = segs[0].file_name().unwrap().to_owned();
    let bytes = std::fs::read(&segs[0]).unwrap();
    let frame_bytes = FRAME_HEADER_BYTES + PER_FRAME * RECORD_BYTES;
    assert_eq!(bytes.len(), FRAMES * frame_bytes, "fixed-width frames");

    let cut_dir = tmp_dir("truncate-cut");
    for cut in 0..=bytes.len() {
        std::fs::remove_dir_all(&cut_dir).ok();
        std::fs::create_dir_all(&cut_dir).unwrap();
        std::fs::write(cut_dir.join(&seg_name), &bytes[..cut]).unwrap();
        let committed_frames = cut / frame_bytes;
        let expect = &events[..committed_frames * PER_FRAME];

        // Reader path: no panic, no phantom events, exact prefix.
        let got = drain(ReplaySource::open(&cut_dir, 0, ReplaySpeed::Max));
        assert_eq!(got, expect, "replay after cut at byte {cut}");

        // Writer path: recovery lands on the same frame boundary and
        // truncates the torn tail away.
        let (_writer, recovery) = SegmentWriter::open(&cut_dir, u64::MAX, false).unwrap();
        assert_eq!(
            recovery.committed_records as usize,
            expect.len(),
            "recovery record count at byte {cut}"
        );
        assert_eq!(
            recovery.truncated_bytes as usize,
            cut - committed_frames * frame_bytes,
            "torn-tail bytes at cut {cut}"
        );
    }
    std::fs::remove_dir_all(&master).ok();
    std::fs::remove_dir_all(&cut_dir).ok();
}

/// Kill mid-spill: a writer that dies mid-frame leaves a torn tail;
/// reopening recovers the committed prefix and the replay of that
/// prefix is byte-identical to the original stream.
#[test]
fn torn_journal_reopens_and_replays_the_committed_prefix() {
    const CHUNK: usize = 256;
    let dir = tmp_dir("torn");
    let events = synthetic_events_seeded(8_000, 128, 128, 0xACED);
    {
        let (capture, _captured) = CaptureSink::new();
        let mut config = DiskBufferConfig::new(dir.clone(), 64 << 20);
        config.fsync_per_batch = false;
        config.front_batches = 1;
        let mut sink = DiskBufferedSink::spawn(Box::new(capture), config, "torn").unwrap();
        for batch in events.chunks(CHUNK) {
            sink.consume(batch).unwrap();
        }
        sink.finish().unwrap();
    }
    // Tear the tail mid-frame, as a crash between write() and the
    // frame's last byte would.
    let last = segment_files(&dir).pop().expect("journal has a segment");
    let len = std::fs::metadata(&last).unwrap().len();
    let file = std::fs::OpenOptions::new().write(true).open(&last).unwrap();
    file.set_len(len - 5).unwrap();
    drop(file);

    let whole_frames = (events.len() / CHUNK) * CHUNK; // the torn frame is the short tail
    let expect = &events[..whole_frames];
    let got = drain(ReplaySource::open(&dir, 0, ReplaySpeed::Max));
    assert_eq!(got, expect, "torn tail must not surface partial frames");

    // Writer recovery truncates to the same boundary and appends cleanly.
    let (mut writer, recovery) = SegmentWriter::open(&dir, u64::MAX, false).unwrap();
    assert_eq!(recovery.committed_records as usize, whole_frames);
    writer.append(&events[whole_frames..]).unwrap();
    writer.sync().unwrap();
    drop(writer);
    assert_eq!(
        drain(ReplaySource::open(&dir, 0, ReplaySpeed::Max)),
        events,
        "recovered journal accepts the re-sent tail"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A sink that holds every batch for a while — the throttled far end
/// that forces the buffered edge to spill.
struct ThrottledSink<S> {
    inner: S,
    delay: Duration,
}

impl<S: EventSink> EventSink for ThrottledSink<S> {
    fn consume(&mut self, batch: &[Event]) -> anyhow::Result<()> {
        std::thread::sleep(self.delay);
        self.inner.consume(batch)
    }
    fn finish(&mut self) -> anyhow::Result<SinkSummary> {
        self.inner.finish()
    }
    fn describe(&self) -> String {
        format!("throttled({})", self.inner.describe())
    }
}

/// The tier-1 acceptance topology: slow sink behind a `disk{cap}` edge
/// completes with zero loss and byte-identical output, spills while
/// running, acks everything, and the journal replays from offset 0 and
/// mid-stream.
#[test]
fn slow_sink_disk_edge_is_lossless_byte_identical_and_replayable() {
    const CHUNK: usize = 173;
    let base = tmp_dir("graph");
    let res = Resolution { width: 96, height: 48 };
    let events = synthetic_events_seeded(12_000, res.width, res.height, 0x5111);

    let (capture, captured) = CaptureSink::new();
    let mut config = DiskBufferConfig::new(base.clone(), 64 << 20);
    config.fsync_per_batch = false;
    config.front_batches = 2;
    let report = Topology::builder()
        .source("in", MemorySource::new(events.clone(), res, CHUNK))
        .sink_buffered(
            "out",
            ThrottledSink { inner: capture, delay: Duration::from_micros(300) },
            config,
        )
        .build()
        .run(GraphConfig { chunk_size: CHUNK, ..Default::default() })
        .unwrap();

    assert_eq!(
        &*captured.lock().unwrap(),
        &events,
        "disk edge must be byte-identical to the memory edge"
    );
    assert_eq!(report.events_in, events.len() as u64);
    assert!(report.buffer_records_spilled > 0, "throttled sink never spilled");
    assert!(report.buffer_bytes_on_disk > 0, "journal gauge never reported");
    assert_eq!(report.buffer_corrupt_records_skipped, 0);
    assert!(!report.buffer_spill_active, "drained edge still flagged as spilling");
    assert_eq!(read_acked_offset(&base), events.len() as u64, "at-least-once ack cursor");

    // The retained journal replays the whole edge, and from mid-stream
    // offsets that land inside frames.
    assert_eq!(drain(ReplaySource::open(&base, 0, ReplaySpeed::Max)), events);
    for offset in [1usize, CHUNK - 1, 5_000, events.len() - 7] {
        assert_eq!(
            drain(ReplaySource::open(&base, offset as u64, ReplaySpeed::Max)),
            events[offset..],
            "replay from offset {offset}"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

/// The memory bound that justifies the subsystem: while the drainer is
/// throttled, the front never holds more than `front_batches` batches
/// in memory — everything else waits on disk.
#[test]
fn memory_front_stays_bounded_while_spilling() {
    const FRONT: usize = 2;
    let dir = tmp_dir("bounded");
    let events = synthetic_events_seeded(10_000, 64, 64, 0xB0B);
    let (capture, captured) = CaptureSink::new();
    let mut config = DiskBufferConfig::new(dir.clone(), 64 << 20);
    config.fsync_per_batch = false;
    config.front_batches = FRONT;
    let mut sink = DiskBufferedSink::spawn(
        Box::new(ThrottledSink { inner: capture, delay: Duration::from_micros(200) }),
        config,
        "bounded",
    )
    .unwrap();
    for batch in events.chunks(100) {
        sink.consume(batch).unwrap();
    }
    sink.finish().unwrap();
    let snap = sink.stats();
    assert_eq!(&*captured.lock().unwrap(), &events, "zero loss");
    assert!(snap.records_spilled > 0, "feeding 100 batches through a slow sink must spill");
    assert!(
        snap.peak_mem_batches <= FRONT as u64,
        "memory front exceeded its bound: peak {} > {FRONT}",
        snap.peak_mem_batches
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Threads of this process whose comm equals `name` exactly.
fn threads_named(name: &str) -> usize {
    let Ok(entries) = std::fs::read_dir("/proc/self/task") else { return 0 };
    entries
        .flatten()
        .filter(|entry| {
            std::fs::read_to_string(entry.path().join("comm"))
                .map(|comm| comm.trim_end() == name)
                .unwrap_or(false)
        })
        .count()
}

/// Serve-plane thread budget: one `buf:w/<edge>` + one `buf:r/<edge>`
/// per buffered edge while it runs, zero after `finish()`.
#[test]
fn buffer_threads_are_named_per_edge_and_reaped_at_finish() {
    if !cfg!(target_os = "linux") {
        return; // /proc census is linux-only
    }
    let dir = tmp_dir("census");
    let (capture, _captured) = CaptureSink::new();
    let mut config = DiskBufferConfig::new(dir.clone(), 1 << 20);
    config.fsync_per_batch = false;
    let mut sink = DiskBufferedSink::spawn(Box::new(capture), config, "census").unwrap();
    sink.consume(&synthetic_events_seeded(1_000, 32, 32, 1)).unwrap();

    // The names are set by the spawned threads themselves; poll briefly.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if threads_named("buf:w/census") == 1 && threads_named("buf:r/census") == 1 {
            break;
        }
        assert!(Instant::now() < deadline, "edge threads never appeared in the census");
        std::thread::sleep(Duration::from_millis(1));
    }

    sink.finish().unwrap();
    assert_eq!(threads_named("buf:w/census"), 0, "writer thread must be reaped");
    assert_eq!(threads_named("buf:r/census"), 0, "drainer thread must be reaped");
    std::fs::remove_dir_all(&dir).ok();
}
