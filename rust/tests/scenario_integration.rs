//! Fig. 4 scenario smoke tests (needs artifacts; skips otherwise).
//!
//! Short recordings, heavy time compression: these assert *invariants*
//! of the scenario runner (event conservation, transfer asymmetry,
//! non-zero frames), not performance — the benches measure that.

use aestream::camera;
use aestream::coordinator::{run_scenario, run_scenario_fused, FeedMode, ScenarioConfig};
use aestream::runtime::{default_artifacts_dir, Device, TransferMode};
use aestream::stream::SliceSource;

fn device_or_skip() -> Option<&'static Device> {
    // One PJRT client per test process, created once and never
    // destroyed: cycling TfrtCpuClient create/destroy per test
    // intermittently segfaults inside the XLA runtime (its background
    // threads outlive the destructor). The CPU client is internally
    // thread-safe; tests only need shared access.
    struct Shared(Option<Device>);
    // SAFETY: the PJRT CPU client is internally synchronized; the Rc
    // handles inside are only cloned/dropped under the test harness's
    // single-threaded schedule (and the static is never dropped).
    unsafe impl Send for Shared {}
    unsafe impl Sync for Shared {}
    static DEVICE: std::sync::OnceLock<Shared> = std::sync::OnceLock::new();
    DEVICE
        .get_or_init(|| {
            let dir = default_artifacts_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return Shared(None);
            }
            Shared(Some(Device::open(&dir).expect("device open")))
        })
        .0
        .as_ref()
}

#[test]
fn all_four_scenarios_conserve_events() {
    let Some(device) = device_or_skip() else { return };
    let recording = camera::paper_recording(60_000, 3); // 60 ms
    let n = recording.len() as u64;
    for cfg in ScenarioConfig::paper_four(4.0) {
        let r = run_scenario(&device, &recording, &cfg).unwrap();
        assert_eq!(r.events, n, "{}: events delivered", r.label);
        assert!(r.frames > 0, "{}: no frames", r.label);
        assert!(r.stats.executions == r.frames, "{}: frame/execution mismatch", r.label);
    }
}

#[test]
fn sparse_moves_fewer_input_bytes_than_dense() {
    let Some(device) = device_or_skip() else { return };
    let recording = camera::paper_recording(60_000, 7);
    let mk = |transfer| ScenarioConfig {
        feed: FeedMode::Threaded { buffer_size: 2048 },
        transfer,
        time_scale: 4.0,
        fetch_outputs: false,
    };
    let dense = run_scenario(&device, &recording, &mk(TransferMode::Dense)).unwrap();
    let sparse = run_scenario(&device, &recording, &mk(TransferMode::Sparse)).unwrap();
    // Per-frame input bytes: dense H·W·4 = 359 840; sparse ≤ 49 152.
    let dense_per_frame = dense.stats.htod_bytes / dense.frames;
    let sparse_per_frame = sparse.stats.htod_bytes / sparse.frames;
    assert!(
        dense_per_frame >= 5 * sparse_per_frame,
        "per-frame bytes: dense {dense_per_frame} vs sparse {sparse_per_frame}"
    );
}

#[test]
fn coroutine_feed_works_with_infinite_time_scale() {
    let Some(device) = device_or_skip() else { return };
    let recording = camera::paper_recording(20_000, 1);
    let cfg = ScenarioConfig {
        feed: FeedMode::Coroutine,
        transfer: TransferMode::Sparse,
        time_scale: f64::INFINITY,
        fetch_outputs: false,
    };
    let r = run_scenario(&device, &recording, &cfg).unwrap();
    assert_eq!(r.events, recording.len() as u64);
    assert!(r.frames >= 1);
}

#[test]
fn fused_sources_conserve_events_into_the_detector() {
    let Some(device) = device_or_skip() else { return };
    // Two sensors on one address plane (§6 fusion): the merged stream
    // must deliver every event of both recordings, in global timestamp
    // order, through the ordinary coroutine scenario path.
    let a = camera::paper_recording(30_000, 11);
    let b = camera::paper_recording(30_000, 12);
    let mut sa = SliceSource::new(&a, 2048);
    let mut sb = SliceSource::new(&b, 2048);
    let cfg = ScenarioConfig {
        feed: FeedMode::Coroutine,
        transfer: TransferMode::Sparse,
        time_scale: f64::INFINITY,
        fetch_outputs: false,
    };
    let r = run_scenario_fused(&device, vec![&mut sa, &mut sb], &cfg).unwrap();
    assert_eq!(r.events, (a.len() + b.len()) as u64);
    assert!(r.frames >= 1);
}

#[test]
fn dropped_events_only_under_capacity_pressure() {
    let Some(device) = device_or_skip() else { return };
    // A quiet recording (sparse dot, no noise) stays far below the
    // 4096-events-per-grab capacity even while the consumer is busy for
    // ~10 ms per step: no silent loss allowed.
    use aestream::camera::{CameraConfig, Scene, SyntheticCamera};
    let quiet = SyntheticCamera::new(CameraConfig {
        scene: Scene::RotatingDot { radius_px: 50.0, period_s: 0.5, dot_radius_px: 4.0 },
        noise_rate_hz: 0.0,
        ..Default::default()
    })
    .record(100_000);
    assert!(!quiet.is_empty());
    let paced = ScenarioConfig {
        feed: FeedMode::Threaded { buffer_size: 1024 },
        transfer: TransferMode::Sparse,
        time_scale: 1.0,
        fetch_outputs: false,
    };
    let r = run_scenario(&device, &quiet, &paced).unwrap();
    assert_eq!(r.dropped, 0, "quiet paced run must not drop events");

    // Flooding the paper-rate recording *may* exceed capacity; whatever
    // happens must be reported, never silently lost.
    let busy = camera::paper_recording(50_000, 2);
    let flood = ScenarioConfig { time_scale: f64::INFINITY, ..paced };
    let r = run_scenario(&device, &busy, &flood).unwrap();
    assert_eq!(r.events, busy.len() as u64);
    assert!(r.dropped <= r.events);
}
