//! Incremental-streaming integration: the O(chunk) memory guarantee,
//! driver equivalence, live UDP sources, and the CLI path.

use std::time::Duration;

use aestream::aer::{Polarity, Resolution};
use aestream::cli;
use aestream::coordinator::{
    run_stream, run_stream_with, Sink, Source, StreamConfig, StreamDriver,
};
use aestream::net::UdpEventSender;
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;
use aestream::stream::{self, MemorySource, NullSink, UdpSource};
use aestream::testutil::synthetic_events;

/// The acceptance bar for the redesign: a million-event source streams
/// through the coroutine driver with peak in-flight events bounded by
/// the configured chunk size — the stream is never materialized.
#[test]
fn million_event_stream_never_materializes() {
    let n = 1_000_000usize;
    let chunk = 4096usize;
    let events = synthetic_events(n, 346, 260);
    let config = StreamConfig {
        chunk_size: chunk,
        driver: StreamDriver::Coroutine { channel_capacity: 1 },
    };
    let report = run_stream_with(
        Source::Memory(events, Resolution::DAVIS_346),
        Pipeline::new(),
        Sink::Null,
        config,
    )
    .unwrap();
    assert_eq!(report.events_in, n as u64);
    assert_eq!(report.events_out, n as u64);
    assert!(
        report.peak_in_flight <= chunk,
        "peak in-flight {} exceeds chunk size {chunk}",
        report.peak_in_flight
    );
    assert_eq!(report.batches, (n as u64).div_ceil(chunk as u64));
    // A rendezvous channel forces producer suspensions: the
    // backpressure gauge must actually move.
    assert!(report.backpressure_waits > 0, "no backpressure observed");
}

#[test]
fn drivers_agree_on_filtered_counts() {
    let events = synthetic_events(20_000, 128, 128);
    let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
    let mut reports = Vec::new();
    for driver in [
        StreamDriver::Sync,
        StreamDriver::Coroutine { channel_capacity: 1 },
        StreamDriver::Coroutine { channel_capacity: 8 },
    ] {
        let report = run_stream_with(
            Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new().then(ops::PolarityFilter::keep(Polarity::On)),
            Sink::Null,
            StreamConfig { chunk_size: 777, driver },
        )
        .unwrap();
        assert_eq!(report.events_in, 20_000, "{driver:?}");
        assert_eq!(report.events_out, on, "{driver:?}");
        reports.push(report);
    }
    // Peak in-flight scales with channel capacity, never past cap×chunk.
    assert!(reports[1].peak_in_flight <= 777);
    assert!(reports[2].peak_in_flight <= 8 * 777);
}

/// Order is preserved through the chunked pipeline: a stateful filter
/// (refractory) sees events in timestamp order exactly as in batch mode.
#[test]
fn stateful_filters_match_batch_processing() {
    let events = synthetic_events(30_000, 64, 64);
    let res = Resolution::new(64, 64);
    let batch_out = Pipeline::new()
        .then(ops::RefractoryFilter::new(res, 300))
        .process(&events)
        .len() as u64;
    let report = run_stream_with(
        Source::Memory(events, res),
        Pipeline::new().then(ops::RefractoryFilter::new(res, 300)),
        Sink::Null,
        StreamConfig { chunk_size: 123, ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.events_out, batch_out);
}

#[test]
fn udp_source_streams_and_ends_on_idle() {
    // Receiver on an ephemeral port, wrapped as a streaming source.
    let rx = aestream::net::UdpEventReceiver::bind("127.0.0.1:0").unwrap();
    let addr = rx.local_addr().unwrap();
    let mut source = UdpSource::from_receiver(rx, Duration::from_millis(250));

    let events = synthetic_events(3000, 346, 260);
    let sender_events = events.clone();
    let sender = std::thread::spawn(move || {
        let mut tx = UdpEventSender::connect(addr).unwrap();
        tx.send(&sender_events).unwrap();
        tx.events_sent
    });

    let report = stream::run(
        &mut source,
        &mut Pipeline::new(),
        &mut NullSink::default(),
        StreamConfig::default(),
    )
    .unwrap();
    let sent = sender.join().unwrap();
    assert_eq!(sent, 3000);
    // Loopback UDP is effectively reliable; the source must terminate
    // via the idle timeout rather than hanging.
    assert_eq!(report.events_in, 3000);
    // Geometry was learned by observation.
    assert!(report.resolution.width > 300);
}

#[test]
fn cli_stream_runs_end_to_end_on_both_drivers() {
    for extra in [&["--chunk", "256"][..], &["--sync"][..]] {
        let mut args = vec![
            "input", "synthetic", "--duration", "20ms", "filter", "polarity", "on", "output",
            "null",
        ];
        args.extend_from_slice(extra);
        let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        match cli::parse(&args).unwrap() {
            cli::Command::Stream { inputs, spec, branches, config, threads, route, .. } => {
                let report = aestream::coordinator::run_graph(
                    inputs,
                    spec,
                    branches,
                    aestream::coordinator::TopologyOptions {
                        config,
                        source_threads: threads > 1,
                        route,
                        ..Default::default()
                    },
                )
                .unwrap();
                assert!(report.events_in > 0, "{extra:?}");
                assert!(report.events_out <= report.events_in, "{extra:?}");
            }
            _ => panic!("expected stream command"),
        }
    }
}

#[test]
fn file_pipeline_file_streams_without_materializing() {
    let dir = std::env::temp_dir().join(format!("aestream-si-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let input = dir.join("in.aeraw");
    let output = dir.join("out.csv");

    let events = synthetic_events(50_000, 346, 260);
    let on: Vec<_> = events.iter().copied().filter(|e| e.p.is_on()).collect();
    run_stream(
        Source::Memory(events, Resolution::DAVIS_346),
        Pipeline::new(),
        Sink::File(input.clone(), aestream::formats::Format::Raw),
    )
    .unwrap();

    let report = run_stream_with(
        Source::file(input),
        Pipeline::new().then(ops::PolarityFilter::keep(Polarity::On)),
        Sink::File(output.clone(), aestream::formats::Format::Text),
        StreamConfig { chunk_size: 512, ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.events_out, on.len() as u64);
    assert!(report.peak_in_flight <= 512);

    let (decoded, res, _) = aestream::formats::read_events_auto(&output).unwrap();
    assert_eq!(decoded, on);
    assert_eq!(res, Resolution::DAVIS_346);
    std::fs::remove_dir_all(&dir).ok();
}

/// The ROADMAP live-source geometry item, end to end: a UDP-fed file
/// sink must not stamp the geometry observed at header-write time (a
/// 1×1 placeholder before any datagram arrives) — it spools lossless
/// records and re-encodes at finish with the exact observed bounding
/// box, so the recorded file reads back identical to the sent stream.
#[test]
fn udp_to_file_records_exact_observed_geometry() {
    let dir = std::env::temp_dir().join(format!("aestream-udpfile-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("live.aedat");

    let rx = aestream::net::UdpEventReceiver::bind("127.0.0.1:0").unwrap();
    let addr = rx.local_addr().unwrap();
    let mut source = UdpSource::from_receiver(rx, Duration::from_millis(250));

    let events = synthetic_events(2000, 346, 260);
    let expected_res = {
        let mut res = Resolution::new(1, 1);
        for ev in &events {
            res.width = res.width.max(ev.x + 1);
            res.height = res.height.max(ev.y + 1);
        }
        res
    };
    let sender_events = events.clone();
    let sender = std::thread::spawn(move || {
        let mut tx = UdpEventSender::connect(addr).unwrap();
        tx.send(&sender_events).unwrap();
    });

    // Geometry unknown (live wire): the sink must take the spool path.
    assert!(!aestream::stream::EventSource::geometry_known(&source));
    let mut sink = aestream::coordinator::Sink::File(path.clone(), aestream::formats::Format::Aedat)
        .into_sink(Resolution::new(1, 1), false)
        .unwrap();
    let report = stream::run(
        &mut source,
        &mut Pipeline::new(),
        sink.as_mut(),
        StreamConfig::default(),
    )
    .unwrap();
    sender.join().unwrap();
    assert_eq!(report.events_in, 2000);

    let (decoded, res, _) = aestream::formats::read_events_auto(&path).unwrap();
    assert_eq!(decoded, events, "spool re-encode must be lossless");
    assert_eq!(res, expected_res, "header must carry the final observed geometry");
    assert!(!path.with_extension("aedat.spool").exists(), "spool cleaned up");
    std::fs::remove_dir_all(&dir).ok();
}

/// The whole point of the chunked memory source: streaming a slice
/// through the driver allocates per-chunk, so even a tiny chunk size
/// completes quickly without ballooning.
#[test]
fn small_chunks_still_drain_completely() {
    let events = synthetic_events(10_000, 64, 64);
    let mut source = MemorySource::new(events, Resolution::new(64, 64), 1);
    let report = stream::run(
        &mut source,
        &mut Pipeline::new(),
        &mut NullSink::default(),
        StreamConfig { chunk_size: 1, driver: StreamDriver::Coroutine { channel_capacity: 1 } },
    )
    .unwrap();
    assert_eq!(report.events_in, 10_000);
    assert_eq!(report.batches, 10_000);
    assert!(report.peak_in_flight <= 1);
}
