//! Equivalence and safety suite for the bulk k-way merge.
//!
//! The loser-tree selection and run galloping in
//! `aestream::stream::merge` must be *observably identical* to the old
//! per-event linear scan — same items, same lanes, same tie-breaks —
//! which `MergeCore::pop_min_linear` preserves verbatim as the oracle.
//! The property drives both cores through the same schedule of pushes,
//! partial drains, blocking flips, and mid-merge lane attach/retire
//! across lane counts 1–5 and segment sizes 1–7, with heavy duplicate
//! keys (the tie-break stress).
//!
//! The pool-safety and zero-copy tests exercise the merge through
//! [`FusedSource`]: recycled batch buffers must never be handed out
//! while a live [`EventChunk`] still views them, and a merge with a
//! single active lane must emit pure run views (zero deep copies).

use aestream::aer::{Event, Polarity, Resolution};
use aestream::stream::merge::MergeCore;
use aestream::stream::{copy_counters, FusedSource, MemorySource};
use aestream::testutil::{synthetic_events, SplitMix64};

#[derive(Clone, Copy)]
enum DrainMode {
    /// Drain the candidate core through `pop_run` (bulk emission).
    Runs,
    /// Drain the candidate core through the tree-based `pop_min`.
    Pops,
}

/// Pop one item from the reference core and assert it matches.
fn expect_linear(lin: &mut MergeCore<(u64, u32)>, want: (usize, (u64, u32)), tag: &str) {
    assert_eq!(lin.pop_min_linear(|it| it.0), Some(want), "{tag}");
}

/// Drive a bulk core and a linear-scan reference core through one
/// identical randomized schedule and assert every emitted (lane, item)
/// pair agrees.
fn run_schedule(k: usize, seg: usize, mode: DrainMode) {
    let seed = 0x9e37_79b9_7f4a_7c15 ^ ((k as u64) << 32) ^ (seg as u64);
    let mut rng = SplitMix64::new(seed);
    let mut bulk: MergeCore<(u64, u32)> = MergeCore::new(k);
    let mut lin: MergeCore<(u64, u32)> = MergeCore::new(k);
    // Per-lane monotone timestamp cursors; tiny increments make
    // duplicate keys common both within and across lanes.
    let mut next_t = vec![0u64; k];
    let mut live = vec![true; k];
    let mut next_id = 0u32;
    for round in 0..8 {
        let tag = format!("k={k} seg={seg} round={round}");
        if round == 3 {
            // A client attaches mid-merge: non-blocking until it
            // delivers, exactly like the serving plane does it.
            assert_eq!(bulk.add_lane(false), lin.add_lane(false), "{tag}");
            next_t.push(0);
            live.push(true);
        }
        if round == 5 && next_t.len() > 1 {
            // And one disconnects: the retired lane drains in order.
            let lane = next_t.len() - 1;
            bulk.retire_lane(lane);
            lin.retire_lane(lane);
            live[lane] = false;
        }
        for lane in 0..next_t.len() {
            if !live[lane] || rng.next_u64() % 4 == 0 {
                continue;
            }
            let n = 1 + (rng.next_u64() as usize % seg);
            let mut batch = Vec::with_capacity(n);
            for _ in 0..n {
                next_t[lane] += rng.next_u64() % 2;
                batch.push((next_t[lane], next_id));
                next_id += 1;
            }
            bulk.push_vec(lane, batch.clone());
            lin.push_vec(lane, batch);
        }
        // Heartbeat-style blocking flips must agree on stall state
        // (they never change pop order, only whether popping is legal).
        let lane = (rng.next_u64() as usize) % next_t.len();
        let blocking = rng.next_u64() % 2 == 0;
        bulk.set_blocking(lane, blocking);
        lin.set_blocking(lane, blocking);
        assert_eq!(bulk.stalled(), lin.stalled(), "{tag}");
        // Partial drain, leaving carries so the next round's pushes
        // land on part-consumed segments.
        for _ in 0..1 + (rng.next_u64() as usize % 3) {
            match mode {
                DrainMode::Runs => {
                    let cap = 1 + (rng.next_u64() as usize % (2 * seg));
                    let Some(run) = bulk.pop_run(cap, |it| it.0) else {
                        break;
                    };
                    assert!(run.len() <= cap, "{tag}: run overran its cap");
                    for &item in run.as_slice() {
                        expect_linear(&mut lin, (run.lane(), item), &tag);
                    }
                }
                DrainMode::Pops => {
                    let Some(got) = bulk.pop_min(|it| it.0) else {
                        break;
                    };
                    expect_linear(&mut lin, got, &tag);
                }
            }
        }
    }
    // Exhaust everything and drain to the end: the tails must agree
    // item-for-item, and both cores must finish together.
    for lane in 0..next_t.len() {
        bulk.exhaust(lane);
        lin.exhaust(lane);
    }
    let tag = format!("k={k} seg={seg} tail");
    loop {
        match bulk.pop_run(usize::MAX, |it| it.0) {
            Some(run) => {
                for &item in run.as_slice() {
                    expect_linear(&mut lin, (run.lane(), item), &tag);
                }
            }
            None => {
                assert_eq!(lin.pop_min_linear(|it| it.0), None, "{tag}");
                break;
            }
        }
    }
    assert!(bulk.all_done() && lin.all_done(), "{tag}");
}

#[test]
fn bulk_runs_match_the_linear_scan_reference() {
    for k in 1..=5 {
        for seg in 1..=7 {
            run_schedule(k, seg, DrainMode::Runs);
        }
    }
}

#[test]
fn tree_pops_match_the_linear_scan_reference() {
    for k in 1..=5 {
        for seg in 1..=7 {
            run_schedule(k, seg, DrainMode::Pops);
        }
    }
}

/// Globally strictly-increasing timestamps alternating between two
/// lanes — every run is one event long, the worst case for buffer
/// churn through the merge's pool.
fn alternating_streams(n: usize) -> (Vec<Event>, Vec<Event>, Vec<Event>) {
    let all: Vec<Event> = (0..n)
        .map(|i| Event {
            t: i as u64,
            x: (i % 64) as u16,
            y: ((i / 64) % 64) as u16,
            p: Polarity::from_bool(i % 2 == 0),
        })
        .collect();
    let a = all.iter().copied().step_by(2).collect();
    let b = all.iter().skip(1).copied().step_by(2).collect();
    (a, b, all)
}

/// Sole-owner reclaim end to end: every chunk the merge emits is held
/// live for the whole run while the merge keeps recycling drained and
/// emitted buffers through its pool. If the pool ever handed a live
/// buffer out again, a later round would overwrite an earlier chunk —
/// caught both against an emission-time snapshot and the merged
/// reference.
#[test]
fn recycled_buffers_never_corrupt_live_chunks() {
    let res = Resolution::new(64, 64);
    let (a, b, expected) = alternating_streams(1200);
    let mut fused = FusedSource::new(
        vec![MemorySource::new(a, res, 64), MemorySource::new(b, res, 64)],
        None,
        100,
    );
    let mut chunks = Vec::new();
    let mut snapshots: Vec<Vec<Event>> = Vec::new();
    while let Some(chunk) = fused.next_chunk().unwrap() {
        snapshots.push(chunk.as_slice().to_vec());
        chunks.push(chunk);
    }
    for (i, (chunk, snap)) in chunks.iter().zip(&snapshots).enumerate() {
        assert_eq!(
            chunk.as_slice(),
            &snap[..],
            "chunk {i} changed after emission: a recycled buffer was overwritten while live"
        );
    }
    let got: Vec<Event> = chunks.iter().flat_map(|c| c.as_slice().iter().copied()).collect();
    assert_eq!(got, expected);
}

/// The acceptance tripwire: a merge whose other lane is exhausted has a
/// single active lane, so every emitted batch must be a zero-copy view
/// of the producer's buffer — no chunk clones, no bytes moved,
/// end to end through `next_chunk`.
#[test]
fn single_active_lane_emits_zero_copy_views() {
    let res = Resolution::new(64, 64);
    let events = synthetic_events(1024, 64, 64);
    let live = MemorySource::new(events.clone(), res, 256);
    let quiet = MemorySource::new(Vec::new(), res, 256);
    // Two inputs force the merged path (no single-source pass-through).
    let mut fused = FusedSource::new(vec![live, quiet], None, 256);
    let before = copy_counters();
    let mut got = Vec::new();
    while let Some(chunk) = fused.next_chunk().unwrap() {
        got.extend_from_slice(chunk.as_slice());
    }
    assert_eq!(got, events);
    let d = copy_counters().delta(&before);
    assert_eq!(d.chunks_cloned, 0, "single-active-lane merge must emit zero-copy run views");
    assert_eq!(d.bytes_moved, 0, "no event may be copied between buffers on this path");
}
