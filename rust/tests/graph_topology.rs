//! Graph-layer acceptance.
//!
//! * **Equivalence property**: every legacy topology shape (1–4
//!   sources × broadcast/polarity/stripes × shards 1–4, inline +
//!   threaded shard workers, plus per-source pump threads) lowered
//!   through `GraphSpec` produces **byte-identical** per-sink output
//!   and matching `StreamReport` node counters versus the pre-redesign
//!   engine entry (`stream::run_topology` with an explicit
//!   `StageGraph`).
//! * **Golden lowering**: CLI clause parsing and the hand-built
//!   `Topology::builder()` chain yield the same `GraphSpec` (compared
//!   by canonical summary).
//! * **Multi-branch**: the CLI's `branch` clauses run one merge into
//!   two independent stage chains and two sinks, with per-branch
//!   `NodeReport`s; with built artifacts, the same shape feeds two
//!   `DetectorSession`s (the ROADMAP's multi-device fan-out).

use aestream::aer::{Event, Resolution};
use aestream::cli::{self, Command};
use aestream::coordinator::{
    lower_to_graph, run_graph, BranchSpec, SessionSink, TopologyOptions,
};
use aestream::pipeline::fusion::SourceLayout;
use aestream::pipeline::{ops, PipelineSpec, StageSpec};
use aestream::runtime::{default_artifacts_dir, Device};
use aestream::stream::{
    run_topology, CaptureSink, FusionLayout, GraphConfig, MemorySource, NullSink, RoutePolicy,
    SourceOptions, StageGraph, StageOptions, StreamConfig, StreamDriver, ThreadMode, Topology,
    TopologyConfig,
};
use aestream::testutil::synthetic_events_seeded;

const RES: Resolution = Resolution { width: 96, height: 48 };

fn stage_spec() -> PipelineSpec {
    PipelineSpec::new()
        .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100)))
        .then(StageSpec::new(|res: Resolution| ops::BackgroundActivityFilter::new(res, 1000)))
}

fn streams(n: usize) -> Vec<Vec<Event>> {
    (0..n)
        .map(|i| synthetic_events_seeded(2400, RES.width, RES.height, 0x9A0 + i as u64))
        .collect()
}

/// Run the pre-redesign engine path: explicit `StageGraph` +
/// `stream::run_topology`, capture sinks.
#[allow(clippy::type_complexity)]
fn run_legacy(
    events: &[Vec<Event>],
    route: RoutePolicy,
    m: usize,
    shards: usize,
    shard_threads: bool,
    source_threads: bool,
) -> (aestream::stream::StreamReport, Vec<Vec<Event>>) {
    let n = events.len();
    let layout =
        (n > 1).then(|| SourceLayout::side_by_side(&vec![RES; n]));
    let canvas = layout.as_ref().map_or(RES, |l| l.canvas);
    let mut graph =
        StageGraph::compile(&stage_spec(), canvas, &StageOptions { shards, shard_threads });
    let sources: Vec<MemorySource> =
        events.iter().map(|e| MemorySource::new(e.clone(), RES, 173)).collect();
    let mut sinks = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..m {
        let (sink, handle) = CaptureSink::new();
        sinks.push(sink);
        handles.push(handle);
    }
    let config = TopologyConfig {
        chunk_size: 173,
        driver: StreamDriver::Coroutine { channel_capacity: 1 },
        threads: if source_threads { ThreadMode::PerSourceThread } else { ThreadMode::Inline },
        route,
        adaptive: None,
        decode_threads: None,
    };
    let report = run_topology(sources, &mut graph, sinks, layout, &config).unwrap();
    let got = handles.iter().map(|h| h.lock().unwrap().clone()).collect();
    (report, got)
}

/// Run the same shape lowered through the graph layer.
#[allow(clippy::type_complexity)]
fn run_graph_shape(
    events: &[Vec<Event>],
    route: RoutePolicy,
    m: usize,
    shards: usize,
    shard_threads: bool,
    source_threads: bool,
) -> (aestream::stream::StreamReport, Vec<Vec<Event>>) {
    let n = events.len();
    let mut builder = Topology::builder();
    let mut names = Vec::new();
    for (i, stream) in events.iter().enumerate() {
        let name = format!("in{i}");
        builder = builder.source_with(
            &name,
            MemorySource::new(stream.clone(), RES, 173),
            SourceOptions { offset: None, threaded: source_threads },
        );
        names.push(name);
    }
    if n > 1 {
        let refs: Vec<&str> = names.iter().map(String::as_str).collect();
        builder = builder.merge_with_layout("fuse", &refs, FusionLayout::SideBySide);
    }
    builder = builder.stages_with("filters", stage_spec(), StageOptions { shards, shard_threads });
    builder = builder.route("split", route);
    let mut handles = Vec::new();
    for j in 0..m {
        let (sink, handle) = CaptureSink::new();
        builder = builder.after("split").sink(&format!("out{j}"), sink);
        handles.push(handle);
    }
    let config = GraphConfig {
        chunk_size: 173,
        driver: StreamDriver::Coroutine { channel_capacity: 1 },
        adaptive: None,
        report_json: None,
        decode_threads: None,
    };
    let report = builder.build().run(config).unwrap();
    let got = handles.iter().map(|h| h.lock().unwrap().clone()).collect();
    (report, got)
}

/// The equivalence property: legacy shapes lowered through `GraphSpec`
/// are byte-identical, sink for sink, with matching node counters.
#[test]
fn every_legacy_shape_lowers_byte_identically() {
    for n in 1..=4usize {
        let events = streams(n);
        for &(route, m) in
            &[(RoutePolicy::Broadcast, 2), (RoutePolicy::Polarity, 2), (RoutePolicy::Stripes, 3)]
        {
            for shards in 1..=4usize {
                for shard_threads in [false, true] {
                    let tag = format!(
                        "n={n} route={route:?} m={m} shards={shards} threads={shard_threads}"
                    );
                    let (legacy, legacy_out) =
                        run_legacy(&events, route, m, shards, shard_threads, false);
                    let (graph, graph_out) =
                        run_graph_shape(&events, route, m, shards, shard_threads, false);
                    assert_eq!(graph_out, legacy_out, "{tag}: sink bytes diverged");
                    assert_eq!(graph.events_in, legacy.events_in, "{tag}");
                    assert_eq!(graph.events_out, legacy.events_out, "{tag}");
                    assert_eq!(graph.resolution, legacy.resolution, "{tag}");
                    assert_eq!(graph.sources.len(), legacy.sources.len(), "{tag}");
                    for (g, l) in graph.sources.iter().zip(&legacy.sources) {
                        assert_eq!(g.events, l.events, "{tag}: source counters");
                        assert_eq!(g.dropped, l.dropped, "{tag}: source drops");
                    }
                    assert_eq!(graph.stages.len(), legacy.stages.len(), "{tag}");
                    for (g, l) in graph.stages.iter().zip(&legacy.stages) {
                        assert_eq!(g.name, l.name, "{tag}: trunk stage names");
                        assert_eq!(g.events, l.events, "{tag}: stage events");
                        assert_eq!(g.dropped, l.dropped, "{tag}: stage drops");
                        assert_eq!(g.shard_events, l.shard_events, "{tag}: shard histogram");
                    }
                    for (g, l) in graph.sinks.iter().zip(&legacy.sinks) {
                        assert_eq!(g.events, l.events, "{tag}: sink counters");
                    }
                    assert_eq!(
                        graph.merge_dropped, legacy.merge_dropped,
                        "{tag}: merge drops"
                    );
                }
            }
        }
    }
}

/// Per-source pump threads through both paths (smaller sweep: thread
/// startup dominates, the equivalence is what matters).
#[test]
fn per_source_threads_lower_byte_identically() {
    let events = streams(3);
    let (legacy, legacy_out) = run_legacy(&events, RoutePolicy::Broadcast, 2, 2, false, true);
    let (graph, graph_out) = run_graph_shape(&events, RoutePolicy::Broadcast, 2, 2, false, true);
    assert_eq!(graph_out, legacy_out, "threaded sources: sink bytes diverged");
    assert_eq!(graph.events_in, legacy.events_in);
    assert_eq!(graph.events_out, legacy.events_out);
    for (g, l) in graph.sources.iter().zip(&legacy.sources) {
        assert_eq!(g.events, l.events);
        assert!(g.name.starts_with("thread("), "graph lane must be pumped: {:?}", g.name);
        assert!(l.name.starts_with("thread("), "legacy lane must be pumped: {:?}", l.name);
    }
}

/// Serial Vec-baseline partition of an already-processed stream: the
/// routing semantics written out longhand over owned `Vec`s, which is
/// exactly what the pre-chunk topology computed.
fn route_reference(
    processed: &[Event],
    route: RoutePolicy,
    m: usize,
    canvas: Resolution,
) -> Vec<Vec<Event>> {
    match route {
        RoutePolicy::Broadcast => vec![processed.to_vec(); m],
        RoutePolicy::Polarity => {
            let (on, off): (Vec<Event>, Vec<Event>) =
                processed.iter().copied().partition(|ev| ev.p.is_on());
            vec![on, off]
        }
        RoutePolicy::Stripes => {
            let stripe = (canvas.width as usize).div_ceil(m).max(1);
            let mut parts = vec![Vec::new(); m];
            for &ev in processed {
                parts[(ev.x as usize / stripe).min(m - 1)].push(ev);
            }
            parts
        }
    }
}

/// The zero-copy currency property: across chunk sizes 1–7 (splitting
/// batches at every alignment), shards 1–4, all three route policies,
/// and inline vs threaded sources+shards, per-sink output is
/// byte-identical to the serial Vec baseline (batch fuse → batch
/// pipeline → longhand partition) — and the streaming core performs
/// **zero** whole-chunk deep copies, asserted through the per-run
/// `chunks_cloned` counters. Any future copy sneaking back into the
/// broadcast/stripe/delivery path trips this test.
#[test]
fn chunk_views_match_the_vec_baseline_with_zero_clones() {
    let events = streams(2);
    let layout = SourceLayout::side_by_side(&[RES, RES]);
    let (fused, _) = aestream::pipeline::fusion::fuse(&[&events[0], &events[1]], &layout);
    let processed = stage_spec().build_pipeline(layout.canvas).process(&fused);
    for &(route, m) in
        &[(RoutePolicy::Broadcast, 2), (RoutePolicy::Polarity, 2), (RoutePolicy::Stripes, 3)]
    {
        let expect = route_reference(&processed, route, m, layout.canvas);
        for chunk in 1..=7usize {
            for shards in 1..=4usize {
                for threaded in [false, true] {
                    let tag = format!(
                        "route={route:?} chunk={chunk} shards={shards} threaded={threaded}"
                    );
                    let mut builder = Topology::builder();
                    for (i, stream) in events.iter().enumerate() {
                        builder = builder.source_with(
                            &format!("in{i}"),
                            MemorySource::new(stream.clone(), RES, chunk),
                            SourceOptions { offset: None, threaded },
                        );
                    }
                    builder = builder
                        .merge_with_layout("fuse", &["in0", "in1"], FusionLayout::SideBySide)
                        .stages_with(
                            "filters",
                            stage_spec(),
                            StageOptions { shards, shard_threads: threaded },
                        )
                        .route("split", route);
                    let mut handles = Vec::new();
                    for j in 0..m {
                        let (sink, handle) = CaptureSink::new();
                        builder = builder.after("split").sink(&format!("out{j}"), sink);
                        handles.push(handle);
                    }
                    let config = GraphConfig {
                        chunk_size: chunk,
                        driver: StreamDriver::Coroutine { channel_capacity: 1 },
                        adaptive: None,
                        report_json: None,
                        decode_threads: None,
                    };
                    let report = builder.build().run(config).unwrap();
                    let got: Vec<Vec<Event>> =
                        handles.iter().map(|h| h.lock().unwrap().clone()).collect();
                    assert_eq!(got, expect, "{tag}: sink bytes diverged from the Vec baseline");
                    assert_eq!(
                        report.chunks_cloned, 0,
                        "{tag}: the streaming core deep-copied a chunk"
                    );
                    for sink in &report.sinks {
                        assert_eq!(
                            sink.chunks_cloned, 0,
                            "{tag}: sink {} cloned its deliveries",
                            sink.name
                        );
                    }
                }
            }
        }
    }
}

/// Golden lowering: parsing CLI clauses and hand-building the same
/// topology with the fluent builder yield the same `GraphSpec`.
#[test]
fn cli_clauses_and_builder_yield_the_same_graph() {
    let args: Vec<String> = [
        "input", "synthetic", "--duration", "50ms", "input", "synthetic", "--duration", "50ms",
        "filter", "denoise", "1000", "branch", "filter", "refractory", "100", "output", "null",
        "branch", "output", "null", "--shards", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let Command::Stream {
        inputs,
        spec,
        branches,
        config,
        threads,
        route,
        layout,
        shards,
        shard_threads,
        sink_threads,
        adaptive,
        report_json,
        decode_threads,
    } = cli::parse(&args).unwrap()
    else {
        panic!("wrong parse");
    };
    let opts = TopologyOptions {
        config,
        source_threads: threads > 1,
        route,
        layout,
        shards,
        shard_threads,
        sink_threads,
        adaptive,
        report_json,
        decode_threads,
    };
    let from_cli = lower_to_graph(inputs, spec, branches, &opts).unwrap();

    let sharded = StageOptions { shards: 2, shard_threads: false };
    let hand = Topology::builder()
        .source(
            "in0",
            aestream::stream::CameraSource::new(aestream::camera::CameraConfig::default(), 50_000),
        )
        .source(
            "in1",
            aestream::stream::CameraSource::new(aestream::camera::CameraConfig::default(), 50_000),
        )
        .merge_with_layout("fuse", &["in0", "in1"], FusionLayout::SideBySide)
        .stages_with(
            "filters",
            PipelineSpec::new().then(StageSpec::new(|res: Resolution| {
                ops::BackgroundActivityFilter::new(res, 1000)
            })),
            sharded,
        )
        .route("split", RoutePolicy::Broadcast)
        .stages_with(
            "branch0",
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100))),
            sharded,
        )
        .sink("out0", NullSink::default())
        .after("split")
        .sink("out1", NullSink::default())
        .build();

    assert_eq!(from_cli.summary(), hand.summary(), "CLI lowering drifted from the builder");
    from_cli.validate().unwrap();
}

/// The acceptance shape end to end through the CLI grammar: one merge,
/// two independent branch chains, two sinks, per-branch `NodeReport`s.
#[test]
fn cli_branch_clauses_run_a_multi_branch_graph() {
    let args: Vec<String> = [
        "input", "synthetic", "--duration", "40ms", "input", "synthetic", "--duration", "40ms",
        "filter", "denoise", "2000", "branch", "filter", "polarity", "on", "output", "null",
        "branch", "filter", "refractory", "100", "output", "frames", "5000", "--chunk", "512",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let Command::Stream { inputs, spec, branches, config, route, layout, .. } =
        cli::parse(&args).unwrap()
    else {
        panic!("wrong parse");
    };
    assert_eq!(branches.len(), 2);
    let report = run_graph(
        inputs,
        spec,
        branches,
        TopologyOptions { config, route, layout, ..Default::default() },
    )
    .unwrap();
    assert!(report.events_in > 0);
    assert_eq!(report.sinks.len(), 2);
    let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
    assert!(
        names.iter().any(|n| *n == "denoise(2000µs)"),
        "shared chain report missing in {names:?}"
    );
    assert!(
        names.iter().any(|n| n.starts_with("branch0/")),
        "branch0 chain report missing in {names:?}"
    );
    assert!(
        names.iter().any(|n| n.starts_with("branch1/")),
        "branch1 chain report missing in {names:?}"
    );
    assert!(report.frames > 0, "the frames branch must bin frames");
}

/// Multi-branch byte-identity against the serial model through the
/// coordinator API (`BranchSpec`s assembled in code).
#[test]
fn branch_chains_match_their_serial_references() {
    use aestream::coordinator::{Input, Sink, Source};
    let a = synthetic_events_seeded(3000, RES.width, RES.height, 0xB1);
    let b = synthetic_events_seeded(2000, RES.width, RES.height, 0xB2);
    let layout = SourceLayout::side_by_side(&[RES, RES]);
    let (fused, _) = aestream::pipeline::fusion::fuse(&[&a, &b], &layout);
    let shared = || {
        PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| ops::BackgroundActivityFilter::new(res, 1500)))
    };
    let on_chain = || {
        PipelineSpec::new()
            .then(StageSpec::new(|_| ops::PolarityFilter::keep(aestream::aer::Polarity::On)))
    };
    let refr_chain = || {
        PipelineSpec::new()
            .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 50)))
    };
    let after_shared = shared().build_pipeline(layout.canvas).process(&fused);
    let expect_on = on_chain().build_pipeline(layout.canvas).process(&after_shared);
    let expect_refr = refr_chain().build_pipeline(layout.canvas).process(&after_shared);

    // Coordinator branches only offer the built-in sinks; use the
    // stream-level builder with capture sinks for byte identity, and
    // the coordinator path for counter plumbing.
    let (sink_on, got_on) = CaptureSink::new();
    let (sink_refr, got_refr) = CaptureSink::new();
    let report = Topology::builder()
        .source("a", MemorySource::new(a.clone(), RES, 256))
        .source("b", MemorySource::new(b.clone(), RES, 256))
        .merge("fuse", &["a", "b"])
        .stages("shared", shared())
        .route("split", RoutePolicy::Broadcast)
        .stages("keep-on", on_chain())
        .sink("on", sink_on)
        .after("split")
        .stages("cooldown", refr_chain())
        .sink("refr", sink_refr)
        .build()
        .run(GraphConfig { chunk_size: 256, ..Default::default() })
        .unwrap();
    assert_eq!(*got_on.lock().unwrap(), expect_on, "polarity branch ≠ serial");
    assert_eq!(*got_refr.lock().unwrap(), expect_refr, "refractory branch ≠ serial");
    assert_eq!(report.sinks[0].events, expect_on.len() as u64);
    assert_eq!(report.sinks[1].events, expect_refr.len() as u64);

    // Same shape through the coordinator's BranchSpec path: counters
    // must line up with the serial model too.
    let report = run_graph(
        vec![
            Input::from(Source::Memory(a, RES)),
            Input::from(Source::Memory(b, RES)),
        ],
        shared(),
        vec![
            BranchSpec { spec: on_chain(), sink: Sink::Null },
            BranchSpec { spec: refr_chain(), sink: Sink::Null },
        ],
        TopologyOptions {
            config: StreamConfig { chunk_size: 256, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.sinks[0].events, expect_on.len() as u64);
    assert_eq!(report.sinks[1].events, expect_refr.len() as u64);
}

// ---------------------------------------------------------------- device

fn device_or_skip() -> Option<&'static Device> {
    // One PJRT client per test process (see scenario_integration.rs for
    // why create/destroy cycles are unsafe).
    struct Shared(Option<Device>);
    // SAFETY: the PJRT CPU client is internally synchronized; the
    // static is never dropped.
    unsafe impl Send for Shared {}
    unsafe impl Sync for Shared {}
    static DEVICE: std::sync::OnceLock<Shared> = std::sync::OnceLock::new();
    DEVICE
        .get_or_init(|| {
            let dir = default_artifacts_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return Shared(None);
            }
            Shared(Some(Device::open(&dir).expect("device open")))
        })
        .0
        .as_ref()
}

/// The ROADMAP's multi-device fan-out: one merged stream, two branch
/// chains, two `DetectorSession` sinks (needs artifacts; skips
/// otherwise).
#[test]
fn fused_stream_fans_out_into_two_detector_sessions() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let plane = Resolution::new(m.width as u16, m.height as u16);
    let a = synthetic_events_seeded(4000, plane.width, plane.height, 0xD1);
    let b = synthetic_events_seeded(4000, plane.width, plane.height, 0xD2);
    let report = Topology::builder()
        .source("a", MemorySource::new(a, plane, 1024))
        .source("b", MemorySource::new(b, plane, 1024))
        .merge_with_layout("fuse", &["a", "b"], FusionLayout::Overlay)
        .route("split", RoutePolicy::Polarity)
        .stages(
            "on-cooldown",
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 50))),
        )
        .sink("det-on", SessionSink::sparse(device).unwrap())
        .after("split")
        .sink("det-off", SessionSink::sparse(device).unwrap())
        .build()
        .run(GraphConfig { chunk_size: 1024, ..Default::default() })
        .unwrap();
    assert_eq!(report.events_in, 8000);
    assert_eq!(report.sinks.len(), 2);
    for sink in &report.sinks {
        assert!(sink.events > 0, "{}: no events reached the session", sink.name);
        assert!(sink.frames > 0, "{}: the session processed no frames", sink.name);
        assert!(sink.name.starts_with("session("), "{:?}", sink.name);
    }
    let names: Vec<&str> = report.stages.iter().map(|s| s.name.as_str()).collect();
    assert!(names.iter().any(|n| n.starts_with("on-cooldown/")), "{names:?}");
}
