//! Failure injection & robustness: hostile inputs must error, never
//! panic, hang, or return corrupt streams silently.

use aestream::aer::Resolution;
use aestream::formats::{detect_format, EventCodec, Format};
use aestream::net::spif;
use aestream::runtime::json::Json;
use aestream::testutil::prop::check;
use aestream::testutil::{synthetic_events, SplitMix64};

/// Random bytes into every decoder: must return Ok or Err, never panic.
#[test]
fn fuzz_codecs_on_random_bytes() {
    for format in Format::ALL {
        check(
            &format!("{format} decoder survives garbage"),
            64,
            |rng: &mut SplitMix64| {
                let len = rng.next_below(512) as usize;
                (0..len).map(|_| rng.next_u64() as u8).collect::<Vec<u8>>()
            },
            |bytes| {
                let codec = format.codec();
                // Any outcome but a panic is acceptable.
                let _ = codec.decode(&mut &bytes[..]);
                true
            },
        );
    }
}

/// Bit-flip a valid encoding: decode must not panic, and when it
/// succeeds the events must still be within sane bounds for the format.
#[test]
fn fuzz_codecs_on_bitflipped_valid_streams() {
    let events = synthetic_events(200, 128, 128);
    let res = Resolution::DVS_128;
    for format in Format::ALL {
        let codec = format.codec();
        let mut clean = Vec::new();
        codec.encode(&events, res, &mut clean).unwrap();
        check(
            &format!("{format} decoder survives bit flips"),
            48,
            |rng: &mut SplitMix64| {
                let mut corrupted = clean.clone();
                for _ in 0..4 {
                    let pos = rng.next_below(corrupted.len() as u64) as usize;
                    let bit = rng.next_below(8) as u8;
                    corrupted[pos] ^= 1 << bit;
                }
                corrupted
            },
            |bytes| {
                let _ = format.codec().decode(&mut &bytes[..]);
                true
            },
        );
    }
}

/// Truncation at every length of a small valid file: no panics.
#[test]
fn codecs_survive_all_truncations() {
    let events = synthetic_events(20, 64, 64);
    let res = Resolution::new(64, 64);
    for format in Format::ALL {
        let codec = format.codec();
        let mut full = Vec::new();
        codec.encode(&events, res, &mut full).unwrap();
        for cut in 0..full.len() {
            let _ = codec.decode(&mut &full[..cut]);
        }
    }
}

/// Format detection never misidentifies another codec's output.
#[test]
fn detection_is_unambiguous_across_formats() {
    let events = synthetic_events(100, 64, 64);
    let res = Resolution::new(64, 64);
    for format in Format::ALL {
        let mut buf = Vec::new();
        format.codec().encode(&events, res, &mut buf).unwrap();
        let sniffed = detect_format(&buf[..buf.len().min(64)]);
        assert_eq!(sniffed, Some(format));
    }
}

/// SPIF decoding of arbitrary word-aligned garbage yields in-range
/// coordinates (the receiver feeds them straight into pipelines).
#[test]
fn spif_garbage_words_stay_in_range() {
    check(
        "spif word range",
        64,
        |rng: &mut SplitMix64| {
            (0..64).flat_map(|_| (rng.next_u64() as u32).to_le_bytes()).collect::<Vec<u8>>()
        },
        |payload| {
            let events = spif::decode_datagram(payload, 0).unwrap();
            events.iter().all(|e| e.x <= 0xFFFF && e.y <= 0x7FFF)
        },
    );
}

/// JSON parser: arbitrary input never panics; valid-prefix slicing of a
/// real manifest errors cleanly.
#[test]
fn json_parser_robustness() {
    check(
        "json garbage",
        64,
        |rng: &mut SplitMix64| {
            let len = rng.next_below(128) as usize;
            (0..len)
                .map(|_| (rng.next_below(94) + 32) as u8 as char)
                .collect::<String>()
        },
        |src| {
            let _ = Json::parse(src);
            true
        },
    );
    let manifest = r#"{"height": 260, "modules": {"a": {"file": "x"}}}"#;
    for cut in 0..manifest.len() {
        let _ = Json::parse(&manifest[..cut]);
    }
}

/// Executor under churn: many short-lived coroutines with interleaved
/// channels complete exactly once each.
#[test]
fn executor_survives_task_churn() {
    use aestream::rt::{channel, LocalExecutor};
    use std::cell::Cell;
    let finished = Cell::new(0u32);
    let finished_ref = &finished;
    let ex = LocalExecutor::new();
    let mut receivers = Vec::new();
    for i in 0..50u64 {
        let (tx, rx) = channel::<u64>(2);
        receivers.push(rx);
        ex.spawn(async move {
            for k in 0..10 {
                if tx.send(i * 10 + k).await.is_err() {
                    return;
                }
            }
        });
    }
    for mut rx in receivers {
        ex.spawn(async move {
            let mut n = 0;
            while rx.recv().await.is_some() {
                n += 1;
            }
            assert_eq!(n, 10);
            finished_ref.set(finished_ref.get() + 1);
        });
    }
    let completed = ex.run();
    assert_eq!(completed, 100);
    assert_eq!(finished.get(), 50);
}
