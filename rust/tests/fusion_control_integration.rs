//! Integration for the §6 future-work modules: fusing two synthetic
//! cameras onto one canvas and closing the control loop on the Rust SNN
//! oracle (the device-backed loop lives in `examples/closed_loop.rs`).

use aestream::aer::{validate_stream, Resolution};
use aestream::camera::{CameraConfig, Scene, SyntheticCamera};
use aestream::control::{track_step, PController, PanActuator};
use aestream::pipeline::backpressure::{BoundedQueue, OverflowPolicy};
use aestream::pipeline::framer::Framer;
use aestream::pipeline::fusion::{fuse, SourceLayout};
use aestream::snn::EdgeDetector;

#[test]
fn two_cameras_fuse_into_one_valid_canvas_stream() {
    let res = Resolution::new(128, 96);
    let cam = |seed: u64, scene: Scene| {
        SyntheticCamera::new(CameraConfig {
            resolution: res,
            scene,
            noise_rate_hz: 1.0,
            frame_interval_us: 1000,
            seed,
        })
        .record(50_000)
    };
    let left = cam(1, Scene::MovingBar { speed_px_per_s: 200.0, thickness_px: 4 });
    let right = cam(2, Scene::RotatingDot { radius_px: 30.0, period_s: 0.4, dot_radius_px: 5.0 });

    let layout = SourceLayout::side_by_side(&[res, res]);
    let (fused, dropped) = fuse(&[&left, &right], &layout);
    assert_eq!(dropped, 0);
    assert_eq!(fused.len(), left.len() + right.len());
    assert_eq!(validate_stream(&fused, layout.canvas), None);

    // Frame the fused canvas: both halves must carry activity.
    let frames = Framer::frames_of(layout.canvas, 10_000, &fused);
    let any_left = frames.iter().any(|f| {
        f.data[..].chunks(layout.canvas.width as usize).any(|row| {
            row[..res.width as usize].iter().any(|&v| v != 0.0)
        })
    });
    let any_right = frames.iter().any(|f| {
        f.data[..].chunks(layout.canvas.width as usize).any(|row| {
            row[res.width as usize..].iter().any(|&v| v != 0.0)
        })
    });
    assert!(any_left && any_right, "both sources must reach the canvas");
}

#[test]
fn control_loop_tracks_through_the_snn_oracle() {
    // Full software loop: camera → framer → Rust LIF+conv → centroid →
    // controller → actuator. The rotating target orbits ±60 px; engaged
    // control must keep the mean |error| well inside that swing.
    let res = Resolution::DAVIS_346;
    let mut detector = EdgeDetector::new(res);
    let controller = PController::new(6.0, 300.0);
    let mut actuator = PanActuator::new(300.0);
    let window = 2_000u64;

    let mut errors = Vec::new();
    for step in 0..60u64 {
        let mut camera = SyntheticCamera::new(CameraConfig {
            resolution: res,
            scene: Scene::RotatingDot { radius_px: 60.0, period_s: 1.0, dot_radius_px: 8.0 },
            noise_rate_hz: 0.0,
            frame_interval_us: window,
            seed: 7,
        });
        let mut events = Vec::new();
        while camera.now_us() < (step + 1) * window {
            let burst = camera.step();
            if camera.now_us() > step * window {
                events.extend(burst);
            }
        }
        // Pan shifts the apparent scene.
        let pan = actuator.position;
        let shifted: Vec<_> = events
            .into_iter()
            .filter_map(|mut ev| {
                let x = ev.x as f32 - pan;
                (x >= 0.0 && x < res.width as f32).then(|| {
                    ev.x = x as u16;
                    ev
                })
            })
            .collect();
        let frames = Framer::frames_of(res, window, &shifted);
        let Some(frame) = frames.last() else { continue };
        let edges = detector.step_frame(frame);
        if let Some(err) = track_step(&edges, res, &controller, &mut actuator, window) {
            errors.push(err.abs());
        }
    }
    assert!(errors.len() > 20, "loop must engage");
    let mean: f32 = errors.iter().sum::<f32>() / errors.len() as f32;
    assert!(mean < 45.0, "tracking mean |error| {mean} vs ±60 px open-loop swing");
}

#[test]
fn backpressure_queue_feeds_framer_without_loss_below_capacity() {
    let res = Resolution::new(64, 64);
    let events = aestream::testutil::synthetic_events(500, 64, 64);
    let mut q = BoundedQueue::new(1024, OverflowPolicy::Reject);
    for ev in &events {
        assert!(q.push(*ev));
    }
    assert_eq!(q.high_watermark, 500);
    let drained = q.drain_all();
    let frames = Framer::frames_of(res, 100, &drained);
    let total: u64 = frames.iter().map(|f| f.event_count).sum();
    assert_eq!(total, 500);
}
