//! Cross-module pipeline integration: files ⇆ pipelines ⇆ network ⇆ CLI.

use std::time::{Duration, Instant};

use aestream::aer::{Polarity, Resolution};
use aestream::camera::{CameraConfig, SyntheticCamera};
use aestream::cli;
use aestream::coordinator::{run_stream, Sink, Source};
use aestream::formats::{self, Format};
use aestream::net::{UdpEventReceiver, UdpEventSender};
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;
use aestream::testutil::synthetic_events;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("aestream-it-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn every_format_survives_a_file_pipeline() {
    let dir = tmpdir("fmt");
    let events = synthetic_events(800, 346, 260);
    let res = Resolution::DAVIS_346;
    for format in Format::ALL {
        let path = dir.join(format!("stream.{}", format.codec().name()));
        formats::write_events(&path, &events, res, format).unwrap();
        let (decoded, dres, detected) = formats::read_events_auto(&path).unwrap();
        assert_eq!(decoded, events, "{format}");
        assert_eq!(dres, res, "{format}");
        assert_eq!(detected, format, "{format}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn camera_to_file_to_filtered_file() {
    let dir = tmpdir("cam");
    let raw_path = dir.join("recording.aedat");
    let on_path = dir.join("on_only.csv");

    // Record a synthetic stream to AEDAT.
    let report = run_stream(
        Source::Synthetic { config: CameraConfig::default(), duration_us: 50_000 },
        Pipeline::new(),
        Sink::File(raw_path.clone(), Format::Aedat),
    )
    .unwrap();
    assert!(report.events_in > 100);

    // Re-read, keep ON polarity, write CSV.
    let filtered = run_stream(
        Source::file(raw_path),
        Pipeline::new().then(ops::PolarityFilter::keep(Polarity::On)),
        Sink::File(on_path.clone(), Format::Text),
    )
    .unwrap();
    assert!(filtered.events_out < filtered.events_in);

    // CSV contains only ON events.
    let (events, _, _) = formats::read_events_auto(&on_path).unwrap();
    assert_eq!(events.len() as u64, filtered.events_out);
    assert!(events.iter().all(|e| e.p.is_on()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn udp_loopback_stream_preserves_payload() {
    let mut rx = UdpEventReceiver::bind("127.0.0.1:0").unwrap();
    let addr = rx.local_addr().unwrap();
    let events = synthetic_events(2000, 346, 260);

    // Sender on a second thread (the normal deployment shape).
    let sender_events = events.clone();
    let sender = std::thread::spawn(move || {
        let mut tx = UdpEventSender::connect(addr).unwrap();
        tx.send(&sender_events).unwrap();
        (tx.datagrams_sent, tx.events_sent)
    });

    let got = rx
        .recv_until(Instant::now() + Duration::from_secs(3), events.len())
        .unwrap();
    let (dgrams, sent) = sender.join().unwrap();
    assert_eq!(sent, 2000);
    assert!(dgrams >= 6);
    assert_eq!(got.len(), events.len());
    for (a, b) in got.iter().zip(&events) {
        assert_eq!((a.x, a.y, a.p), (b.x, b.y, b.p));
    }
}

#[test]
fn camera_stream_through_full_filter_chain() {
    // A realistic chain: denoise → refractory → crop → downsample.
    let res = Resolution::DAVIS_346;
    let recording = SyntheticCamera::new(CameraConfig::default()).record(100_000);
    let mut pipeline = Pipeline::new()
        .then(ops::BackgroundActivityFilter::new(res, 5000))
        .then(ops::RefractoryFilter::new(res, 500))
        .then(ops::RoiCrop::new(20, 20, 300, 220))
        .then(ops::Downsample::new(2));
    let out = pipeline.process(&recording);
    assert!(!out.is_empty(), "structured motion must survive the chain");
    assert!(out.len() < recording.len(), "filters must thin the stream");
    assert!(out.iter().all(|e| e.x < 150 && e.y < 110));
}

#[test]
fn cli_parse_and_run_synthetic_to_null() {
    let args: Vec<String> = [
        "input", "synthetic", "--duration", "20ms", "filter", "polarity", "on", "output", "null",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    match cli::parse(&args).unwrap() {
        cli::Command::Stream { inputs, spec, branches, config, threads, route, .. } => {
            let report = aestream::coordinator::run_graph(
                inputs,
                spec,
                branches,
                aestream::coordinator::TopologyOptions {
                    config,
                    source_threads: threads > 1,
                    route,
                    ..Default::default()
                },
            )
            .unwrap();
            assert!(report.events_in > 0);
        }
        _ => panic!("expected stream command"),
    }
}

#[test]
fn engines_drive_pipeline_workloads_identically() {
    // The coroutine engine and the sync baseline must see identical
    // pipeline results (order preserved).
    let events = synthetic_events(5000, 128, 128);
    let collect = |engine_coro: bool| -> Vec<aestream::aer::Event> {
        let mut out = Vec::new();
        if engine_coro {
            aestream::engine::coro::for_each(&events, |e| out.push(*e));
        } else {
            aestream::engine::sync::for_each(&events, |e| out.push(*e));
        }
        out
    };
    assert_eq!(collect(true), collect(false));
}
