//! Fan-in/fan-out topology integration: streaming-merge correctness at
//! word-splitting chunk sizes, the O(chunk × sources) memory bound
//! under per-source OS threads, and the CLI acceptance invocation.

use anyhow::Result;

use aestream::aer::{validate_stream, Event, Resolution};
use aestream::cli;
use aestream::coordinator::{self, TopologyOptions};
use aestream::pipeline::fusion::{self, SourceLayout};
use aestream::pipeline::{Pipeline, PipelineSpec};
use aestream::stream::{
    run_topology, EventSink, EventSource, FusedSource, MemorySource, RoutePolicy, SinkSummary,
    StreamConfig, StreamDriver, ThreadMode, TopologyConfig,
};
use aestream::testutil::prop::check;
use aestream::testutil::SplitMix64;

/// A sink that fails the run on any global-order or canvas violation.
struct OrderSink {
    canvas: Resolution,
    last_t: u64,
    events: u64,
}

impl OrderSink {
    fn new(canvas: Resolution) -> Self {
        OrderSink { canvas, last_t: 0, events: 0 }
    }
}

impl EventSink for OrderSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        for ev in batch {
            anyhow::ensure!(
                ev.t >= self.last_t,
                "timestamp regression: {} after {}",
                ev.t,
                self.last_t
            );
            anyhow::ensure!(
                self.canvas.contains(ev),
                "event ({},{}) outside canvas {}",
                ev.x,
                ev.y,
                self.canvas
            );
            self.last_t = ev.t;
            self.events += 1;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }

    fn describe(&self) -> String {
        "order-check".into()
    }
}

/// Random per-source event streams (individually time-ordered).
fn gen_streams(rng: &mut SplitMix64, max_sources: usize) -> (Vec<Vec<Event>>, Resolution) {
    let k = 1 + (rng.next_u64() as usize) % max_sources;
    let width = 8 + (rng.next_u64() % 56) as u16;
    let height = 8 + (rng.next_u64() % 56) as u16;
    let streams = (0..k)
        .map(|_| {
            let n = (rng.next_u64() % 300) as usize;
            let mut t = 0u64;
            (0..n)
                .map(|_| {
                    t += rng.next_u64() % 5;
                    Event {
                        t,
                        x: (rng.next_u64() % width as u64) as u16,
                        y: (rng.next_u64() % height as u64) as u16,
                        p: aestream::aer::Polarity::from_bool(rng.next_u64() & 1 == 1),
                    }
                })
                .collect()
        })
        .collect();
    (streams, Resolution::new(width, height))
}

/// Property: at any chunk size (including word-splitting ones), the
/// streaming k-way merge emits exactly the batch `fusion::fuse` result —
/// globally timestamp-ordered, canvas-bounded, deterministic on ties —
/// while buffering at most `sources × chunk` events.
#[test]
fn prop_streaming_merge_preserves_order_and_bounds() {
    check(
        "streaming merge ≡ batch fuse",
        48,
        |rng| {
            let (streams, res) = gen_streams(rng, 4);
            let chunk = 1 + (rng.next_u64() as usize) % 7; // tiny: forces splits
            (streams, res, chunk)
        },
        |(streams, res, chunk)| {
            let layout = SourceLayout::side_by_side(&vec![*res; streams.len()]);
            let refs: Vec<&[Event]> = streams.iter().map(|s| s.as_slice()).collect();
            let (expected, expected_dropped) = fusion::fuse(&refs, &layout);

            let sources: Vec<MemorySource> = streams
                .iter()
                .map(|s| MemorySource::new(s.clone(), *res, *chunk))
                .collect();
            let mut fused = FusedSource::new(sources, Some(layout.clone()), *chunk);
            let mut got = Vec::new();
            loop {
                match fused.next_batch().unwrap() {
                    None => break,
                    Some(batch) => got.extend(batch),
                }
            }
            got == expected
                && fused.dropped() == expected_dropped
                && fused.peak_buffered() <= streams.len() * *chunk
                && validate_stream(&got, layout.canvas).is_none()
        },
    );
}

/// Acceptance: a ≥2-source (one OS thread each), ≥2-sink topology
/// streams end to end through the coroutine driver with globally
/// timestamp-ordered delivery and O(chunk · sources) peak memory.
#[test]
fn threaded_topology_is_ordered_and_memory_bounded() {
    let res = Resolution::new(128, 128);
    let chunk = 512usize;
    let a = aestream::testutil::synthetic_events_seeded(60_000, 128, 128, 100);
    let b = aestream::testutil::synthetic_events_seeded(40_000, 128, 128, 200);
    let sources =
        vec![MemorySource::new(a, res, chunk), MemorySource::new(b, res, chunk)];
    let canvas = Resolution::new(256, 128); // side-by-side of two 128×128
    let sinks = vec![OrderSink::new(canvas), OrderSink::new(canvas)];
    let config = TopologyConfig {
        chunk_size: chunk,
        driver: StreamDriver::Coroutine { channel_capacity: 1 },
        threads: ThreadMode::PerSourceThread,
        route: RoutePolicy::Broadcast,
        adaptive: None,
        decode_threads: None,
    };
    let report =
        run_topology(sources, &mut Pipeline::new(), sinks, None, &config).unwrap();
    assert_eq!(report.events_in, 100_000);
    assert_eq!(report.events_out, 100_000);
    assert_eq!(report.resolution, canvas);
    // Per-node attribution.
    assert_eq!(report.sources.len(), 2);
    assert_eq!(report.sources[0].events, 60_000);
    assert_eq!(report.sources[1].events, 40_000);
    assert_eq!(report.sinks.len(), 2);
    assert!(report.sinks.iter().all(|s| s.events == 100_000), "broadcast delivery");
    // O(chunk · sources): the merge's carry buffers hold at most one
    // batch per source, and the edge channel at most capacity × chunk.
    assert!(
        report.merge_peak_buffered <= 2 * chunk,
        "merge buffered {} > sources × chunk",
        report.merge_peak_buffered
    );
    assert!(
        report.peak_in_flight <= chunk,
        "edge peak {} > capacity × chunk",
        report.peak_in_flight
    );
}

/// The exact acceptance-criteria CLI invocation parses and runs:
/// `input synthetic … input synthetic … output file … output null
/// --threads 2`.
#[test]
fn acceptance_cli_two_inputs_two_outputs_two_threads() {
    let dir = std::env::temp_dir().join(format!("aestream-topo-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fused.aedat");

    let args: Vec<String> = [
        "input",
        "synthetic",
        "--duration",
        "30ms",
        "input",
        "synthetic",
        "--duration",
        "30ms",
        "output",
        "file",
        path.to_str().unwrap(),
        "output",
        "null",
        "--threads",
        "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();

    let report = match cli::parse(&args).unwrap() {
        cli::Command::Stream { inputs, spec, branches, config, threads, route, .. } => {
            assert_eq!(inputs.len(), 2);
            assert_eq!(branches.len(), 2);
            assert_eq!(threads, 2);
            coordinator::run_graph(
                inputs,
                spec,
                branches,
                TopologyOptions {
                    config,
                    source_threads: threads > 1,
                    route,
                    ..Default::default()
                },
            )
            .unwrap()
        }
        _ => panic!("expected stream command"),
    };
    assert!(report.events_in > 0);
    // Two DAVIS346 cameras side by side.
    assert_eq!(report.resolution, Resolution::new(692, 260));
    assert_eq!(report.sources.len(), 2);
    assert_eq!(report.sinks.len(), 2);

    // The recorded file holds the full fused stream: time-ordered, on
    // the fused canvas, complete.
    let (decoded, res, _) = aestream::formats::read_events_auto(&path).unwrap();
    assert_eq!(decoded.len() as u64, report.events_in);
    assert_eq!(res, Resolution::new(692, 260));
    assert_eq!(validate_stream(&decoded, res), None);
    // Both halves of the canvas received events.
    assert!(decoded.iter().any(|e| e.x < 346));
    assert!(decoded.iter().any(|e| e.x >= 346));
    std::fs::remove_dir_all(&dir).ok();
}

/// Polarity fan-out over the sync baseline driver: the two outputs
/// exactly partition the stream.
#[test]
fn sync_topology_polarity_split_partitions() {
    let events = aestream::testutil::synthetic_events(10_000, 64, 64);
    let on = events.iter().filter(|e| e.p.is_on()).count() as u64;
    let report = coordinator::run_topology(
        vec![coordinator::Source::Memory(events, Resolution::new(64, 64)).into()],
        PipelineSpec::new(),
        vec![coordinator::Sink::Null, coordinator::Sink::Null],
        TopologyOptions {
            config: StreamConfig::sync(),
            route: RoutePolicy::Polarity,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.sinks[0].events, on);
    assert_eq!(report.sinks[1].events, 10_000 - on);
    assert_eq!(report.sinks[0].events + report.sinks[1].events, report.events_out);
}

/// Fused file sources: two recordings written independently merge into
/// one ordered canvas stream with per-source counters intact.
#[test]
fn two_file_sources_fuse_side_by_side() {
    let dir = std::env::temp_dir().join(format!("aestream-fusefile-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let left = dir.join("left.aeraw");
    let right = dir.join("right.aeraw");
    let a = aestream::testutil::synthetic_events_seeded(3000, 128, 128, 7);
    let b = aestream::testutil::synthetic_events_seeded(2000, 128, 128, 8);
    for (path, events) in [(&left, &a), (&right, &b)] {
        coordinator::run_stream(
            coordinator::Source::Memory(events.clone(), Resolution::DVS_128),
            Pipeline::new(),
            coordinator::Sink::File(path.clone(), aestream::formats::Format::Raw),
        )
        .unwrap();
    }

    let report = coordinator::run_topology(
        vec![coordinator::Source::file(left).into(), coordinator::Source::file(right).into()],
        PipelineSpec::new(),
        vec![coordinator::Sink::Null],
        TopologyOptions::default(),
    )
    .unwrap();
    assert_eq!(report.events_in, 5000);
    assert_eq!(report.resolution, Resolution::new(256, 128));
    assert_eq!(report.sources[0].events, 3000);
    assert_eq!(report.sources[1].events, 2000);
    assert_eq!(report.merge_dropped, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Headerless recordings (text format records no geometry) are barred
/// from fusion *unless* the operator declares their geometry — and the
/// declaration claims exact extents, so the fused canvas is exact.
#[test]
fn headerless_recordings_fuse_with_declared_geometry() {
    let dir = std::env::temp_dir().join(format!("aestream-headerless-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let left = dir.join("left.csv");
    let right = dir.join("right.csv");
    let a = aestream::testutil::synthetic_events_seeded(1500, 64, 64, 21);
    let b = aestream::testutil::synthetic_events_seeded(1500, 64, 64, 22);
    for (path, events) in [(&left, &a), (&right, &b)] {
        coordinator::run_stream(
            coordinator::Source::Memory(events.clone(), Resolution::new(64, 64)),
            Pipeline::new(),
            coordinator::Sink::File(path.clone(), aestream::formats::Format::Text),
        )
        .unwrap();
    }

    // Undeclared: rejected with the actionable hint.
    let err = coordinator::run_topology(
        vec![
            coordinator::Source::file(left.clone()).into(),
            coordinator::Source::file(right.clone()).into(),
        ],
        PipelineSpec::new(),
        vec![coordinator::Sink::Null],
        TopologyOptions::default(),
    )
    .unwrap_err();
    assert!(format!("{err}").contains("--geometry"));

    // Declared: fuses side by side on the exact declared canvas.
    let geom = Some(Resolution::new(64, 64));
    let report = coordinator::run_topology(
        vec![
            coordinator::Source::File { path: left, geometry: geom }.into(),
            coordinator::Source::File { path: right, geometry: geom }.into(),
        ],
        PipelineSpec::new(),
        vec![coordinator::Sink::Null],
        TopologyOptions::default(),
    )
    .unwrap();
    assert_eq!(report.events_in, 3000);
    assert_eq!(report.resolution, Resolution::new(128, 64));
    assert_eq!(report.merge_dropped, 0);
    let dropped: u64 = report.sources.iter().map(|s| s.dropped).sum();
    assert_eq!(dropped, 0, "everything fits the declared claim");
    std::fs::remove_dir_all(&dir).ok();
}
