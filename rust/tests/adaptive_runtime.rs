//! Adaptive-runtime acceptance at the topology level: the `skew`
//! controller re-cuts a hotspot workload into balance (lower final
//! `shard_skew` than the static cut), re-cuts keep the full-topology
//! output byte-identical to serial, and the reconfiguration history
//! lands in `StreamReport.adaptive`.

use anyhow::Result;

use aestream::aer::{Event, Resolution};
use aestream::pipeline::{ops, PipelineSpec, StageSpec};
use aestream::stream::{
    run_topology, run_topology_with_adaptive, AdaptiveConfig, AdaptiveRuntime, Controller,
    ControllerKind, EpochSample, EventSink, MemorySource, Reconfigure, SinkSummary,
    StageGraph, StageOptions, StreamDriver, TopologyConfig,
};
use aestream::testutil::hotspot_events_seeded;

/// Sink that records every delivered event, in order.
#[derive(Default)]
struct CollectSink {
    events: Vec<Event>,
}

impl EventSink for CollectSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        self.events.extend_from_slice(batch);
        Ok(())
    }
    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }
    fn describe(&self) -> String {
        "collect".into()
    }
}

fn refractory_spec() -> PipelineSpec {
    PipelineSpec::new()
        .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 3)))
}

fn run_hotspot(adaptive: Option<AdaptiveConfig>) -> (aestream::stream::StreamReport, Vec<Event>) {
    let res = Resolution::new(128, 64);
    let events = hotspot_events_seeded(40_000, 128, 64, 0xADA);
    let spec = refractory_spec();
    let mut graph =
        StageGraph::compile(&spec, res, &StageOptions { shards: 4, shard_threads: false });
    let config = TopologyConfig {
        chunk_size: 256,
        driver: StreamDriver::Coroutine { channel_capacity: 1 },
        adaptive,
        ..Default::default()
    };
    let mut sink = CollectSink::default();
    let report = run_topology(
        vec![MemorySource::new(events, res, 256)],
        &mut graph,
        vec![&mut sink],
        None,
        &config,
    )
    .unwrap();
    (report, sink.events)
}

/// The acceptance criterion: on a hotspot stream, `--adaptive skew`
/// ends with a lower final `shard_skew` than the static uniform cut —
/// and the adaptive run's output is still byte-identical to serial.
#[test]
fn skew_controller_beats_the_static_cut_on_a_hotspot() {
    let res = Resolution::new(128, 64);
    let events = hotspot_events_seeded(40_000, 128, 64, 0xADA);
    let serial = refractory_spec().build_pipeline(res).process(&events);

    let (static_report, static_out) = run_hotspot(None);
    let (adaptive_report, adaptive_out) = run_hotspot(Some(
        AdaptiveConfig::new(vec![ControllerKind::Skew]).with_epoch(8),
    ));

    assert_eq!(static_out, serial, "static sharded run must match serial");
    assert_eq!(adaptive_out, serial, "adaptive re-cuts must not change the output");

    let static_skew = static_report.stages[0].shard_skew();
    let adaptive_skew = adaptive_report.stages[0].shard_skew();
    // 90% of traffic in one uniform stripe of four ⇒ skew near 3.6.
    assert!(static_skew > 2.0, "hotspot must skew the static cut, got {static_skew}");
    assert!(
        adaptive_skew < static_skew,
        "adaptive final skew {adaptive_skew} must beat static {static_skew}"
    );
    assert!(adaptive_skew < 1.5, "re-cuts should converge near balance, got {adaptive_skew}");

    let history = adaptive_report.adaptive.expect("adaptive history");
    assert!(history.epochs >= 2);
    assert!(!history.recuts.is_empty(), "the hotspot must trigger at least one re-cut");
    let first = &history.recuts[0];
    assert_eq!(first.stage, 0);
    assert!(
        first.skew_after < first.skew_before,
        "recorded re-cut must predict an improvement ({} → {})",
        first.skew_before,
        first.skew_after
    );
    assert!(static_report.adaptive.is_none(), "static runs report no history");
}

/// A hostile custom controller that re-cuts every single epoch through
/// the real driver (coroutine consumer path): output must stay
/// byte-identical to serial, and the history must record every cut.
struct PingPong {
    flip: bool,
}

impl Controller for PingPong {
    fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
        self.flip = !self.flip;
        let bound = if self.flip { 24 } else { 100 };
        sample
            .stages
            .iter()
            .filter(|s| s.bounds.len() == 2)
            .map(|s| Reconfigure::RecutStripes { stage: s.stage, bounds: vec![bound, 128] })
            .collect()
    }
    fn describe(&self) -> String {
        "ping-pong".into()
    }
}

#[test]
fn forced_recuts_through_the_driver_stay_byte_identical() {
    let res = Resolution::new(128, 64);
    let events = hotspot_events_seeded(20_000, 128, 64, 0xBEEF);
    let spec = PipelineSpec::new().then(StageSpec::new(|res: Resolution| {
        ops::BackgroundActivityFilter::new(res, 40)
    }));
    let serial = spec.build_pipeline(res).process(&events);

    for driver in [StreamDriver::Coroutine { channel_capacity: 1 }, StreamDriver::Sync] {
        let mut graph = StageGraph::compile(
            &spec,
            res,
            &StageOptions { shards: 2, shard_threads: false },
        );
        let config = TopologyConfig { chunk_size: 128, driver, ..Default::default() };
        let adaptive = AdaptiveRuntime {
            epoch_batches: 1, // re-cut at every batch barrier
            controllers: vec![Box::new(PingPong { flip: false })],
        };
        let mut sink = CollectSink::default();
        let report = run_topology_with_adaptive(
            vec![MemorySource::new(events.clone(), res, 128)],
            &mut graph,
            vec![&mut sink],
            None,
            &config,
            Some(adaptive),
        )
        .unwrap();
        assert_eq!(sink.events, serial, "{driver:?}: per-epoch re-cuts diverged");
        let history = report.adaptive.expect("history");
        assert!(
            history.recuts.len() as u64 >= history.epochs.saturating_sub(1),
            "{driver:?}: every epoch but possibly the last must re-cut \
             ({} cuts over {} epochs)",
            history.recuts.len(),
            history.epochs
        );
    }
}

/// Registration round-trip for the pluggable controller registry: a
/// third-party `Controller` registered by name resolves through the
/// CLI's `--adaptive` parser into a config, builds at topology start,
/// and actually acts on the run — end to end, no
/// `run_topology_with_adaptive` plumbing required.
#[test]
fn registered_controller_works_end_to_end_from_a_name() {
    use aestream::stream::adapt::{parse_controllers, registry};

    /// Clamp the chunk to 64 at the first epoch (easy to observe).
    struct Clamp;
    impl Controller for Clamp {
        fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
            if sample.chunk_size != 64 {
                vec![Reconfigure::ChunkSize(64)]
            } else {
                Vec::new()
            }
        }
        fn describe(&self) -> String {
            "clamp(64)".into()
        }
    }
    registry::register_controller("clamp64", || Box::new(Clamp)).unwrap();

    // The CLI-facing name list resolves the custom controller…
    let kinds = parse_controllers("clamp64").unwrap();
    assert_eq!(kinds, vec![ControllerKind::Custom("clamp64".into())]);
    // …and the resulting config drives a real topology.
    let res = Resolution::new(64, 64);
    let events = hotspot_events_seeded(8000, 64, 64, 0x77);
    let mut graph = StageGraph::compile(
        &refractory_spec(),
        res,
        &StageOptions { shards: 2, shard_threads: false },
    );
    let config = TopologyConfig {
        chunk_size: 512,
        adaptive: Some(AdaptiveConfig::new(kinds).with_epoch(2)),
        ..Default::default()
    };
    let report = run_topology(
        vec![MemorySource::new(events, res, 512)],
        &mut graph,
        vec![aestream::stream::NullSink::default()],
        None,
        &config,
    )
    .unwrap();
    let history = report.adaptive.expect("adaptive history");
    assert_eq!(history.final_chunk, 64, "the registered controller must act");
    assert_eq!(history.chunk_changes[0].from, 512);
    assert_eq!(history.chunk_changes[0].to, 64);
    // Unknown names fail loudly when the config builds.
    let missing = AdaptiveConfig::new(vec![ControllerKind::Custom("no-such".into())]);
    let err = format!(
        "{:?}",
        run_topology(
            vec![MemorySource::new(Vec::new(), res, 64)],
            &mut aestream::pipeline::Pipeline::new(),
            vec![aestream::stream::NullSink::default()],
            None,
            &TopologyConfig { adaptive: Some(missing), ..Default::default() },
        )
        .unwrap_err()
    );
    assert!(err.contains("not registered"), "got {err}");
}

/// The per-epoch histogram lane: controllers see each epoch's traffic
/// in isolation (not the cumulative run), which is what makes skew
/// decisions converge instead of being dominated by stale history.
#[test]
fn epoch_samples_carry_per_epoch_not_cumulative_histograms() {
    let res = Resolution::new(64, 64);
    let events = hotspot_events_seeded(4096, 64, 64, 7);
    let spec = refractory_spec();
    let mut graph =
        StageGraph::compile(&spec, res, &StageOptions { shards: 2, shard_threads: false });
    let config = TopologyConfig { chunk_size: 256, ..Default::default() };
    // Every epoch of 4 × 256-event batches must show ~1024 events,
    // never the cumulative total (asserted inside the controller, which
    // panics the run on violation).
    struct Checker;
    impl Controller for Checker {
        fn observe(&mut self, sample: &EpochSample) -> Vec<Reconfigure> {
            let epoch_events: u64 =
                sample.stages[0].epoch_shard_events.iter().sum();
            // The consumer processes exactly 4 × 256 events per epoch;
            // a cumulative histogram would show sample.epoch × 1024.
            assert_eq!(
                epoch_events,
                4 * 256,
                "epoch {} histogram is not per-epoch",
                sample.epoch
            );
            assert_eq!(sample.batches, 4);
            Vec::new()
        }
        fn describe(&self) -> String {
            "checker".into()
        }
    }
    let adaptive =
        AdaptiveRuntime { epoch_batches: 4, controllers: vec![Box::new(Checker)] };
    let report = run_topology_with_adaptive(
        vec![MemorySource::new(events, res, 256)],
        &mut graph,
        vec![aestream::stream::NullSink::default()],
        None,
        &config,
        Some(adaptive),
    )
    .unwrap();
    assert_eq!(report.adaptive.expect("history").epochs, 4, "4096 / (4×256)");
}
