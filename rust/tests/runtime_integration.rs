//! Runtime integration: AOT artifacts × PJRT device × Rust oracle.
//!
//! These tests require `make artifacts` to have run (they skip politely
//! otherwise) and validate the full cross-language contract:
//!
//! * the HLO `scatter_only` module equals the Rust framer on real events;
//! * the HLO `lif_only` module equals `snn::lif` bit-for-bit-ish;
//! * the dense and sparse sessions track the pure-Rust `EdgeDetector`
//!   over multi-frame streams (state feedback through the device);
//! * dense and sparse sessions agree with each other;
//! * transfer accounting observes the documented byte asymmetry.

use aestream::aer::Resolution;
use aestream::camera;
use aestream::pipeline::framer::Framer;
use aestream::runtime::{
    default_artifacts_dir, DetectorSession, Device, TransferMode, TransferStats,
};
use aestream::snn::EdgeDetector;
use aestream::testutil::synthetic_events;

fn device_or_skip() -> Option<&'static Device> {
    // One PJRT client per test process, created once and never
    // destroyed: cycling TfrtCpuClient create/destroy per test
    // intermittently segfaults inside the XLA runtime (its background
    // threads outlive the destructor). The CPU client is internally
    // thread-safe; tests only need shared access.
    struct Shared(Option<Device>);
    // SAFETY: the PJRT CPU client is internally synchronized; the Rc
    // handles inside are only cloned/dropped under the test harness's
    // single-threaded schedule (and the static is never dropped).
    unsafe impl Send for Shared {}
    unsafe impl Sync for Shared {}
    static DEVICE: std::sync::OnceLock<Shared> = std::sync::OnceLock::new();
    DEVICE
        .get_or_init(|| {
            let dir = default_artifacts_dir();
            if !dir.join("manifest.json").exists() {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return Shared(None);
            }
            Shared(Some(Device::open(&dir).expect("device open")))
        })
        .0
        .as_ref()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

#[test]
fn scatter_module_matches_rust_framer() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let res = Resolution::new(m.width as u16, m.height as u16);
    let module = device.load("scatter_only").expect("load scatter_only");
    let mut stats = TransferStats::new();

    let events = synthetic_events(3000, res.width, res.height);
    let (lit, dropped) =
        aestream::runtime::device::events_literal(&events, m.max_events).unwrap();
    assert_eq!(dropped, 0);
    let buf = device.to_device(&lit, &mut stats).unwrap();
    let out = device.execute(&module, &[&buf], &mut stats).unwrap();
    let parts = device.from_device(&out, &mut stats).unwrap();
    assert_eq!(parts.len(), 1);
    let frame_dev = parts[0].to_vec::<f32>().unwrap();

    // Rust oracle: bin all events into one frame.
    let mut frame = aestream::pipeline::framer::Frame::zeroed(res, 0, u64::MAX);
    for ev in &events {
        frame.accumulate(ev);
    }
    assert_close(&frame_dev, &frame.data, 0.0, "scatter vs framer");
    assert_eq!(stats.htod_ops, 1);
    assert_eq!(stats.htod_bytes, (m.max_events * 12) as u64);
}

#[test]
fn lif_module_matches_rust_lif() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let n = m.width * m.height;
    let module = device.load("lif_only").expect("load lif_only");
    let mut stats = TransferStats::new();

    // Deterministic pseudo-random input, voltage, refractory planes.
    let mut rng = aestream::testutil::SplitMix64::new(99);
    let x: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32 * 2.0).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.next_f64() as f32).collect();
    let r: Vec<f32> = (0..n).map(|_| (rng.next_below(4)) as f32).collect();

    let mk = |d: &[f32]| aestream::runtime::device::frame_literal(d, m.height, m.width).unwrap();
    let bufs = [
        device.to_device(&mk(&x), &mut stats).unwrap(),
        device.to_device(&mk(&v), &mut stats).unwrap(),
        device.to_device(&mk(&r), &mut stats).unwrap(),
    ];
    let out = device
        .execute(&module, &[&bufs[0], &bufs[1], &bufs[2]], &mut stats)
        .unwrap();
    let parts = device.from_device(&out, &mut stats).unwrap();
    assert_eq!(parts.len(), 3);
    let (s_dev, v_dev, r_dev) = (
        parts[0].to_vec::<f32>().unwrap(),
        parts[1].to_vec::<f32>().unwrap(),
        parts[2].to_vec::<f32>().unwrap(),
    );

    // Rust oracle.
    let params = aestream::snn::LifParams::default();
    let mut state = aestream::snn::LifState {
        v: v.clone(),
        r: r.iter().map(|&f| f as u32).collect(),
    };
    let spikes = aestream::snn::lif::lif_step(&params, &mut state, &x);

    assert_close(&s_dev, &spikes, 0.0, "lif spikes");
    assert_close(&v_dev, &state.v, 1e-5, "lif voltage");
    let r_rust: Vec<f32> = state.r.iter().map(|&u| u as f32).collect();
    assert_close(&r_dev, &r_rust, 0.0, "lif refractory");
}

#[test]
fn dense_session_tracks_rust_oracle_over_stream() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let res = Resolution::new(m.width as u16, m.height as u16);

    let recording = camera::paper_recording(30_000, 5); // 30 ms
    let frames = Framer::frames_of(res, 1000, &recording);
    assert!(frames.len() >= 10, "need a real stream, got {}", frames.len());

    let mut session = DetectorSession::new(&device, TransferMode::Dense).unwrap();
    let mut oracle = EdgeDetector::new(res);
    for frame in frames.iter().take(15) {
        let out = session.step_dense(&frame.data).unwrap();
        let (spikes, edges) = oracle.step_full(&frame.data);
        assert_close(&out.spikes, &spikes, 0.0, "spikes");
        assert_close(&out.edges, &edges, 1e-4, "edges");
    }
}

#[test]
fn sparse_session_equals_dense_session() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let res = Resolution::new(m.width as u16, m.height as u16);

    let recording = camera::paper_recording(20_000, 9);
    let frames = Framer::frames_of(res, 1000, &recording);

    let mut dense = DetectorSession::new(&device, TransferMode::Dense).unwrap();
    let mut sparse = DetectorSession::new(&device, TransferMode::Sparse).unwrap();

    let mut window_events = Vec::new();
    let mut idx = 0usize;
    for frame in frames.iter().take(10) {
        // Reconstruct the window's raw events for the sparse path.
        window_events.clear();
        while idx < recording.len() && recording[idx].t < frame.t_end {
            if recording[idx].t >= frame.t_start {
                window_events.push(recording[idx]);
            }
            idx += 1;
        }
        let d = dense.step_dense(&frame.data).unwrap();
        let s = sparse.step_sparse(&window_events).unwrap();
        assert_eq!(s.dropped_events, 0);
        assert_close(&d.spikes, &s.spikes, 0.0, "dense vs sparse spikes");
        assert_close(&d.edges, &s.edges, 1e-4, "dense vs sparse edges");
    }

    // The documented byte asymmetry: dense input bytes ≫ sparse.
    assert!(
        dense.stats.htod_bytes > 5 * sparse.stats.htod_bytes,
        "dense {} vs sparse {} input bytes",
        dense.stats.htod_bytes,
        sparse.stats.htod_bytes
    );
    // Both modes are one HtoD input op per frame.
    assert_eq!(dense.stats.htod_ops, sparse.stats.htod_ops);
}

#[test]
fn sparse_session_counts_dropped_events() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    let mut session = DetectorSession::new(&device, TransferMode::Sparse).unwrap();
    let too_many = synthetic_events(m.max_events + 500, m.width as u16, m.height as u16);
    let out = session.step_sparse(&too_many).unwrap();
    assert_eq!(out.dropped_events, 500);
}

#[test]
fn manifest_geometry_matches_paper() {
    let Some(device) = device_or_skip() else { return };
    let m = device.manifest();
    assert_eq!((m.height, m.width), (260, 346), "paper's DAVIS346 geometry");
    assert!(m.max_events >= 1024);
}
