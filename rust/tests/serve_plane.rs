//! Serving-plane integration: the PR-6 acceptance criteria.
//!
//! * a `tcp-listen` topology serves 100+ concurrent loopback clients
//!   with exactly-once delivery, per-client `NodeReport`s that sum to
//!   the merge input, and merge memory bounded by `clients × window`;
//! * clients attach mid-stream and abrupt disconnects (including a
//!   torn word) end their lanes cleanly;
//! * the AIMD client-window controller demonstrably shrinks windows
//!   under a throttled sink, the history lands in
//!   `StreamReport::adaptive` and in `--report-json` output, and
//!   delivery stays fair (max/min accepted ratio ≤ 2);
//! * the `subscribe` sink fans every delivery out to all consumers and
//!   evicts a slow one instead of blocking the trunk;
//! * HTTP `POST` ingest feeds the same plane.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use aestream::aer::{Event, Resolution};
use aestream::coordinator::{run_graph, Sink, Source, TopologyOptions};
use aestream::net::spif;
use aestream::pipeline::PipelineSpec;
use aestream::serve::{ClientHub, ListenerConfig, ListenerSource, SubscribeSink};
use aestream::stream::{
    AdaptiveConfig, ControllerKind, EventSink, GraphConfig, MemorySource, ReportTarget,
    SinkSummary, StreamReport, Topology,
};

// ------------------------------------------------------------- helpers

/// SPIF-over-TCP wire bytes for `events` (little-endian words).
fn wire_bytes(events: &[Event]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(events.len() * 4);
    for ev in events {
        bytes.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
    }
    bytes
}

/// `count` events all at column `x` (so the sink can attribute each
/// delivered event to the client that sent it).
fn column_events(x: u16, count: usize, height: u16) -> Vec<Event> {
    (0..count).map(|j| Event::on(x, (j % height as usize) as u16, j as u64)).collect()
}

/// Spin until `cond` holds (serving-plane state is asynchronous).
fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(1));
    }
}

/// Close the hub once every expected client was admitted and has
/// disconnected — the test-side stand-in for an operator's shutdown.
fn shutdown_when_drained(hub: &Arc<ClientHub>, expected: u64) -> thread::JoinHandle<()> {
    let hub = hub.clone();
    thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(30);
        while (hub.admitted() < expected || hub.active_clients() > 0) && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(1));
        }
        hub.shutdown();
    })
}

/// Per-column event counter, optionally throttled to simulate a slow
/// downstream consumer (which is what makes the AIMD controller act).
struct ColumnCountSink {
    counts: Arc<Mutex<Vec<u64>>>,
    delay: Duration,
}

impl ColumnCountSink {
    fn new(columns: usize, delay: Duration) -> (Self, Arc<Mutex<Vec<u64>>>) {
        let counts = Arc::new(Mutex::new(vec![0u64; columns]));
        (ColumnCountSink { counts: counts.clone(), delay }, counts)
    }
}

impl EventSink for ColumnCountSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        {
            let mut counts = self.counts.lock().unwrap();
            for ev in batch {
                counts[ev.x as usize] += 1;
            }
        }
        if !self.delay.is_zero() {
            thread::sleep(self.delay);
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }
}

fn client_reports(report: &StreamReport) -> Vec<&aestream::metrics::NodeReport> {
    report.sources.iter().filter(|n| n.name.starts_with("client:")).collect()
}

fn temp_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("aestream-serve-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("report.jsonl")
}

// --------------------------------------------------------------- tests

/// The headline acceptance test: 100 concurrent loopback clients, each
/// its own merge lane, with exactly-once delivery and bounded memory.
#[test]
fn hundred_clients_stream_exactly_once_with_bounded_memory() {
    const CLIENTS: usize = 100;
    const PER_CLIENT: usize = 8_000;
    // The reader's 16 KiB buffer caps wire batches at 4096 events, so
    // a window of 4096 makes `clients × window` the true high-water
    // mark for both the credit ledgers and the merge carries.
    const WINDOW: usize = 4096;

    let res = Resolution::new(128, 128);
    let listener = ListenerSource::bind_tcp(
        "127.0.0.1:0",
        ListenerConfig::new(res).window(WINDOW).max_clients(CLIENTS + 8),
    )
    .unwrap();
    let addr = listener.local_addr();
    let hub = listener.hub();

    let senders: Vec<_> = (0..CLIENTS)
        .map(|i| {
            thread::spawn(move || {
                let bytes = wire_bytes(&column_events(i as u16, PER_CLIENT, res.height));
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.write_all(&bytes).unwrap();
            })
        })
        .collect();
    let supervisor = shutdown_when_drained(&hub, CLIENTS as u64);

    let (sink, counts) = ColumnCountSink::new(res.width as usize, Duration::ZERO);
    let report = Topology::builder()
        .listen("net", listener)
        .sink("out", sink)
        .build()
        .run(GraphConfig { chunk_size: 1024, ..Default::default() })
        .unwrap();
    for sender in senders {
        sender.join().unwrap();
    }
    supervisor.join().unwrap();

    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(report.events_in, total, "merge lost or duplicated events");
    assert_eq!(report.merge_dropped, 0);
    let counts = counts.lock().unwrap();
    for (x, &n) in counts.iter().enumerate().take(CLIENTS) {
        assert_eq!(n, PER_CLIENT as u64, "client {x} was not delivered exactly once");
    }
    assert_eq!(counts.iter().sum::<u64>(), total);

    let clients = client_reports(&report);
    assert_eq!(clients.len(), CLIENTS, "every client publishes a NodeReport");
    for node in &clients {
        assert_eq!(node.events, PER_CLIENT as u64, "{} is off", node.name);
    }
    assert_eq!(clients.iter().map(|n| n.events).sum::<u64>(), report.events_in);

    // Bounded memory: the whole 800k-event stream never piles up — the
    // merge's reorder depth stays under clients × window.
    assert!(
        report.merge_peak_buffered <= CLIENTS * WINDOW,
        "merge buffered {} events, over the {} bound",
        report.merge_peak_buffered,
        CLIENTS * WINDOW,
    );
    assert_eq!(hub.admitted(), CLIENTS as u64);
    assert_eq!(hub.refused(), 0);
}

/// Clients may attach while the merge is already running, and an
/// abrupt disconnect — even mid-word — ends the lane cleanly.
#[test]
fn clients_attach_mid_stream_and_abrupt_disconnects_are_clean() {
    let res = Resolution::new(64, 64);
    let listener =
        ListenerSource::bind_tcp("127.0.0.1:0", ListenerConfig::new(res).max_clients(8)).unwrap();
    let addr = listener.local_addr();
    let hub = listener.hub();

    let control = {
        let hub = hub.clone();
        thread::spawn(move || {
            // First client connects and stays attached...
            let mut first = TcpStream::connect(addr).unwrap();
            first.write_all(&wire_bytes(&column_events(1, 100, res.height))).unwrap();
            wait_until("first client admitted", || hub.admitted() >= 1);
            // ...while a second attaches mid-stream and leaves.
            let mut second = TcpStream::connect(addr).unwrap();
            second.write_all(&wire_bytes(&column_events(2, 100, res.height))).unwrap();
            drop(second);
            // A third sends one complete word plus half of another and
            // vanishes: the torn tail must be discarded, not crash.
            let mut torn = TcpStream::connect(addr).unwrap();
            let mut bytes = wire_bytes(&column_events(3, 1, res.height));
            bytes.extend_from_slice(&[0xAA, 0xBB]);
            torn.write_all(&bytes).unwrap();
            drop(torn);
            wait_until("all three admitted", || hub.admitted() >= 3);
            drop(first);
        })
    };
    let supervisor = shutdown_when_drained(&hub, 3);

    let (sink, counts) = ColumnCountSink::new(res.width as usize, Duration::ZERO);
    let report = Topology::builder()
        .listen("net", listener)
        .sink("out", sink)
        .build()
        .run(GraphConfig { chunk_size: 256, ..Default::default() })
        .unwrap();
    control.join().unwrap();
    supervisor.join().unwrap();

    assert_eq!(report.events_in, 201, "100 + 100 + the torn client's one whole word");
    let counts = counts.lock().unwrap();
    assert_eq!((counts[1], counts[2], counts[3]), (100, 100, 1));
    assert_eq!(client_reports(&report).len(), 3);
    assert_eq!(hub.disconnected(), 3);
}

/// Under a throttled sink the AIMD controller shrinks per-client
/// windows; the change history reaches both `StreamReport::adaptive`
/// and the `--report-json` stream, and delivery stays fair.
#[test]
fn aimd_shrinks_windows_under_a_throttled_sink_and_reports_history() {
    const CLIENTS: usize = 8;
    const PER_CLIENT: usize = 40_960;

    let res = Resolution::new(64, 64);
    let listener = ListenerSource::bind_tcp(
        "127.0.0.1:0",
        ListenerConfig::new(res).window(256).max_clients(CLIENTS),
    )
    .unwrap();
    let addr = listener.local_addr();
    let hub = listener.hub();

    let senders: Vec<_> = (0..CLIENTS)
        .map(|i| {
            thread::spawn(move || {
                let bytes = wire_bytes(&column_events(i as u16, PER_CLIENT, res.height));
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.write_all(&bytes).unwrap();
            })
        })
        .collect();
    let supervisor = shutdown_when_drained(&hub, CLIENTS as u64);

    let path = temp_path("aimd");
    let (sink, _counts) = ColumnCountSink::new(res.width as usize, Duration::from_millis(2));
    let report = Topology::builder()
        .listen("net", listener)
        .sink("out", sink)
        .build()
        .run(GraphConfig {
            chunk_size: 4096,
            adaptive: Some(AdaptiveConfig::new(vec![ControllerKind::ClientWindow]).with_epoch(8)),
            report_json: Some(ReportTarget::File(path.clone())),
            ..Default::default()
        })
        .unwrap();
    for sender in senders {
        sender.join().unwrap();
    }
    supervisor.join().unwrap();

    assert_eq!(report.events_in, (CLIENTS * PER_CLIENT) as u64);
    let adaptive = report.adaptive.as_ref().expect("adaptive history");
    assert!(adaptive.epochs > 0);
    assert!(
        adaptive.window_changes.iter().any(|c| c.to < c.from),
        "AIMD never shrank a window despite a throttled sink: {:?}",
        adaptive.window_changes,
    );
    for change in &adaptive.window_changes {
        assert!(change.client.starts_with("client:"), "change on {:?}", change.client);
    }

    // Fairness: equal-rate clients end within 2× of each other.
    let clients = client_reports(&report);
    assert_eq!(clients.len(), CLIENTS);
    let max = clients.iter().map(|n| n.events).max().unwrap();
    let min = clients.iter().map(|n| n.events).min().unwrap();
    assert!(min > 0 && max <= 2 * min, "unfair delivery: max {max} vs min {min}");

    // The same history streamed as JSON lines while the run was live.
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.lines().any(|l| l.starts_with("{\"type\":\"epoch\"")), "no epoch lines");
    assert!(json.contains("\"window\":"), "epoch lines carry client windows");
    let last = json.lines().last().unwrap();
    assert!(last.starts_with("{\"type\":\"final\""), "final report line missing");
    assert!(last.contains("\"window_changes\":[{\"epoch\":"), "history absent from final line");
}

/// The subscribe sink fans every delivery to all consumers, and a
/// consumer that stops reading is evicted instead of stalling the rest.
#[test]
fn subscribers_fan_out_and_slow_consumers_are_evicted() {
    // Fan-out: two consumers each receive the full byte-exact stream.
    let res = Resolution::new(64, 64);
    let events: Vec<Event> =
        (0..5_000u16).map(|j| Event::on(j % 64, (j / 64) % 64, u64::from(j))).collect();
    let sink = SubscribeSink::bind("127.0.0.1:0").unwrap();
    let addr = sink.local_addr();
    let mut consumers = [TcpStream::connect(addr).unwrap(), TcpStream::connect(addr).unwrap()];
    wait_until("both subscribers attached", || sink.subscriber_count() == 2);

    let report = Topology::builder()
        .source("mem", MemorySource::new(events.clone(), res, 512))
        .sink("out", sink)
        .build()
        .run(GraphConfig { chunk_size: 512, ..Default::default() })
        .unwrap();
    assert_eq!(report.events_out, events.len() as u64);

    let expected = wire_bytes(&events);
    for consumer in &mut consumers {
        let mut got = Vec::new();
        consumer.read_to_end(&mut got).unwrap();
        assert_eq!(got, expected, "subscriber missed or reordered deliveries");
    }

    // Eviction: one consumer never reads; a healthy one keeps going.
    let mut sink = SubscribeSink::bind("127.0.0.1:0").unwrap();
    let addr = sink.local_addr();
    let stuck = TcpStream::connect(addr).unwrap();
    let healthy = TcpStream::connect(addr).unwrap();
    wait_until("both subscribers attached", || sink.subscriber_count() == 2);
    let drainer = thread::spawn(move || {
        let mut healthy = healthy;
        let mut total = 0usize;
        let mut buf = [0u8; 16 * 1024];
        loop {
            match healthy.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => total += n,
            }
        }
        total
    });

    let batch = column_events(5, 4096, res.height);
    let payload = batch.len() * 4;
    for _ in 0..5_000 {
        sink.consume(&batch).unwrap();
        if sink.evictions() == 1 {
            break;
        }
        // Pace the trunk so the healthy drainer keeps up: eviction must
        // single out the consumer that actually stopped reading.
        thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(sink.evictions(), 1, "the stuck consumer was never evicted");
    assert_eq!(sink.subscriber_count(), 1, "the healthy consumer must survive");
    let summary = sink.finish().unwrap();
    assert!(summary.dropped > 0, "evicted consumer's missed deliveries are counted");
    drop(sink);

    let drained = drainer.join().unwrap();
    assert!(drained > 0 && drained % payload == 0, "healthy consumer saw torn frames");
    drop(stuck);
}

/// HTTP `POST` ingest rides the same hub: framed words in, a JSON
/// accept count out, out-of-canvas events filtered at the door.
#[test]
fn http_post_ingest_feeds_the_graph() {
    let res = Resolution::new(64, 64);
    let listener =
        ListenerSource::bind_http("127.0.0.1:0", ListenerConfig::new(res).max_clients(4)).unwrap();
    let addr = listener.local_addr();
    let hub = listener.hub();

    let poster = thread::spawn(move || {
        let mut body = wire_bytes(&column_events(7, 10, res.height));
        // Two events off the 64×64 canvas: filtered, not accepted.
        body.extend_from_slice(&wire_bytes(&[Event::on(200, 1, 0), Event::on(201, 1, 0)]));
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let head = format!(
            "POST /events HTTP/1.1\r\nHost: t\r\ncontent-length: {}\r\n\r\n",
            body.len()
        );
        stream.write_all(head.as_bytes()).unwrap();
        stream.write_all(&body).unwrap();
        let mut response = Vec::new();
        let mut buf = [0u8; 1024];
        let deadline = Instant::now() + Duration::from_secs(5);
        while !response.ends_with(b"}\n") && Instant::now() < deadline {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => response.extend_from_slice(&buf[..n]),
            }
        }
        String::from_utf8_lossy(&response).into_owned()
    });
    let supervisor = shutdown_when_drained(&hub, 1);

    let (sink, counts) = ColumnCountSink::new(res.width as usize, Duration::ZERO);
    let report = Topology::builder()
        .listen("net", listener)
        .sink("out", sink)
        .build()
        .run(GraphConfig { chunk_size: 64, ..Default::default() })
        .unwrap();
    let response = poster.join().unwrap();
    supervisor.join().unwrap();

    assert!(response.starts_with("HTTP/1.1 200 OK"), "bad response: {response:?}");
    assert!(response.contains("{\"accepted\":10}"), "bad response: {response:?}");
    assert_eq!(report.events_in, 10);
    assert_eq!(counts.lock().unwrap()[7], 10);
    let clients = client_reports(&report);
    assert_eq!(clients.len(), 1);
    assert!(clients[0].name.starts_with("http:"), "HTTP lanes are named http:N");
}

/// The coordinator lowers `input tcp-listen` clauses to listener graph
/// nodes end to end (bind, serve, idle-timeout shutdown, report).
#[test]
fn coordinator_lowers_tcp_listen_clauses_end_to_end() {
    // Probe a free port: the listener binds inside `run_graph`, so the
    // address must be known to the client beforehand.
    let port = std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port();
    let bind = format!("127.0.0.1:{port}");
    let res = Resolution::new(64, 64);
    let config = ListenerConfig::new(res).idle_timeout(Duration::from_millis(800));

    let runner = thread::spawn(move || {
        run_graph(
            vec![Source::TcpListen { bind, config }.into()],
            PipelineSpec::new(),
            vec![Sink::Null.into()],
            TopologyOptions::default(),
        )
        .unwrap()
    });

    // The listener may not be up yet: retry the connect briefly.
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut stream = loop {
        match TcpStream::connect((std::net::Ipv4Addr::LOCALHOST, port)) {
            Ok(stream) => break stream,
            Err(err) => {
                assert!(Instant::now() < deadline, "listener never came up: {err}");
                thread::sleep(Duration::from_millis(5));
            }
        }
    };
    stream.write_all(&wire_bytes(&column_events(9, 100, res.height))).unwrap();
    drop(stream);

    let report = runner.join().unwrap();
    assert_eq!(report.events_in, 100);
    assert_eq!(report.sinks.len(), 1);
    assert_eq!(report.sinks[0].events, 100);
    assert_eq!(client_reports(&report).len(), 1);
}

/// Keep the helper honest: a `SocketAddr` round-trips through the
/// senders unchanged (guards against accidental v6/v4 mixups when the
/// tests are edited).
#[test]
fn loopback_binds_resolve_to_ipv4() {
    let listener =
        ListenerSource::bind_tcp("127.0.0.1:0", ListenerConfig::new(Resolution::new(8, 8)))
            .unwrap();
    let addr: SocketAddr = listener.local_addr();
    assert!(addr.ip().is_loopback());
    listener.hub().shutdown();
}
