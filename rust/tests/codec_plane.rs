//! Codec-plane integration: the PR-9 acceptance criteria.
//!
//! * Decode through the shared worker pool is **byte-identical** to
//!   inline [`StreamingDecoder`] decode for every format, under
//!   randomized submit sizes (torn words, split headers, one-byte
//!   dribbles) and 1–4 workers — the reassembly contract.
//! * A 64-client `tcp-listen` topology with `decode_threads` set keeps
//!   the decode thread census at exactly the budget `W` while every
//!   client's events are delivered exactly once.

use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use aestream::aer::{Event, Resolution};
use aestream::formats::streaming::StreamingDecoder;
use aestream::formats::{EventCodec, Format};
use aestream::net::spif;
use aestream::serve::{ClientHub, ListenerConfig, ListenerSource};
use aestream::stream::{
    CodecPlane, CodecPlaneConfig, EventSink, GraphConfig, SinkSummary, Topology,
};
use aestream::testutil::{synthetic_events_seeded, SplitMix64};

/// Both tests spawn `codec:` threads and one of them censuses the
/// process for that name, so they must not overlap in time.
static PLANE_LOCK: Mutex<()> = Mutex::new(());

// ------------------------------------------------------------- helpers

/// Inline reference decode: one pass through [`StreamingDecoder`].
fn inline_decode(format: Format, bytes: &[u8]) -> (Vec<Event>, Option<Resolution>) {
    let mut dec = StreamingDecoder::new(format);
    let mut out = Vec::new();
    dec.feed(bytes, &mut out).unwrap();
    dec.finish(&mut out).unwrap();
    (out, dec.resolution())
}

/// Pooled decode of `bytes` submitted in the given piece sizes.
fn pooled_decode(
    plane: &Arc<CodecPlane>,
    format: Format,
    bytes: &[u8],
    sizes: &[usize],
) -> (Vec<Event>, Option<Resolution>) {
    let mut stream = plane.open_stream(format);
    let mut out = Vec::new();
    let mut offset = 0;
    let mut sizes = sizes.iter().cycle();
    while offset < bytes.len() {
        let take = (*sizes.next().unwrap()).min(bytes.len() - offset);
        stream.submit(&bytes[offset..offset + take]).unwrap();
        offset += take;
        stream.poll(&mut out).unwrap();
    }
    stream.finish().unwrap();
    while !stream.done() {
        stream.poll_wait(&mut out).unwrap();
    }
    (out, stream.resolution())
}

/// SPIF-over-TCP wire bytes for `events` (little-endian words).
fn wire_bytes(events: &[Event]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(events.len() * 4);
    for ev in events {
        bytes.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
    }
    bytes
}

/// `count` events all at column `x`, so the sink can attribute each
/// delivered event to the client that sent it.
fn column_events(x: u16, count: usize, height: u16) -> Vec<Event> {
    (0..count).map(|j| Event::on(x, (j % height as usize) as u16, j as u64)).collect()
}

/// Close the hub once every expected client was admitted and drained.
fn shutdown_when_drained(hub: &Arc<ClientHub>, expected: u64) -> thread::JoinHandle<()> {
    let hub = hub.clone();
    thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(30);
        while (hub.admitted() < expected || hub.active_clients() > 0)
            && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(1));
        }
        hub.shutdown();
    })
}

/// Threads of this process currently named `codec:<i>`.
fn codec_thread_count() -> usize {
    let Ok(entries) = std::fs::read_dir("/proc/self/task") else { return 0 };
    entries
        .flatten()
        .filter(|entry| {
            std::fs::read_to_string(entry.path().join("comm"))
                .map(|comm| comm.trim_end().starts_with("codec:"))
                .unwrap_or(false)
        })
        .count()
}

struct ColumnCountSink {
    counts: Arc<Mutex<Vec<u64>>>,
}

impl EventSink for ColumnCountSink {
    fn consume(&mut self, batch: &[Event]) -> Result<()> {
        let mut counts = self.counts.lock().unwrap();
        for ev in batch {
            counts[ev.x as usize] += 1;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<SinkSummary> {
        Ok(SinkSummary::default())
    }
}

// --------------------------------------------------------------- tests

/// The reassembly contract: pooled decode ≡ inline decode, for every
/// format, any worker count, and adversarial submit chunking.
#[test]
fn randomized_piece_sizes_decode_identically_across_worker_counts() {
    let _guard = PLANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let res = Resolution::DAVIS_346;
    let events = synthetic_events_seeded(20_000, res.width, res.height, 0x9_CAFE);
    for format in Format::ALL {
        let mut bytes = Vec::new();
        format.codec().encode(&events, res, &mut bytes).unwrap();
        let (inline_events, inline_res) = inline_decode(format, &bytes);
        assert_eq!(inline_events, events, "{format}: codec round-trip broke");
        for workers in 1..=4usize {
            let plane = CodecPlane::new(CodecPlaneConfig::with_workers(workers));
            let mut rng = SplitMix64::new(0x9A5_5EED ^ workers as u64);
            for round in 0..3 {
                // Random sizes from 1 byte (worst-case torn words and
                // split headers) up past the 64 KiB piece target.
                let sizes: Vec<usize> = (0..64)
                    .map(|_| 1 + rng.next_below(100_000) as usize)
                    .collect();
                let (got, got_res) = pooled_decode(&plane, format, &bytes, &sizes);
                assert_eq!(
                    got, inline_events,
                    "{format}: workers={workers} round={round} diverged from inline"
                );
                assert_eq!(got_res, inline_res, "{format}: geometry diverged");
            }
        }
    }
}

/// The serving-plane budget: 64 concurrent clients share exactly `W`
/// decode threads, and every event still arrives exactly once.
#[test]
fn sixty_four_clients_share_a_bounded_decode_pool_exactly_once() {
    let _guard = PLANE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    const CLIENTS: usize = 64;
    const PER_CLIENT: usize = 4_000;
    const WORKERS: usize = 3;

    let res = Resolution::new(128, 128);
    let listener = ListenerSource::bind_tcp(
        "127.0.0.1:0",
        ListenerConfig::new(res).window(4096).max_clients(CLIENTS + 8),
    )
    .unwrap();
    let addr = listener.local_addr();
    let hub = listener.hub();

    // Senders connect only once the topology has attached the decode
    // plane: clients admitted earlier would (correctly) decode inline.
    let senders: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let hub = hub.clone();
            thread::spawn(move || {
                let deadline = Instant::now() + Duration::from_secs(10);
                while hub.decode_plane().is_none() {
                    assert!(Instant::now() < deadline, "decode plane never attached");
                    thread::sleep(Duration::from_millis(1));
                }
                let bytes = wire_bytes(&column_events(i as u16, PER_CLIENT, res.height));
                let mut stream = TcpStream::connect(addr).unwrap();
                // Several writes per client so reads interleave and the
                // plane sees many small submits, not one per client.
                for piece in bytes.chunks(8192) {
                    stream.write_all(piece).unwrap();
                }
            })
        })
        .collect();
    let supervisor = shutdown_when_drained(&hub, CLIENTS as u64);

    // Census the decode threads while the run is live.
    let census_hub = hub.clone();
    let census = thread::spawn(move || {
        let mut peak = 0;
        while !census_hub.is_closed() {
            peak = peak.max(codec_thread_count());
            thread::sleep(Duration::from_millis(1));
        }
        peak
    });

    let counts = Arc::new(Mutex::new(vec![0u64; res.width as usize]));
    let sink = ColumnCountSink { counts: counts.clone() };
    let report = Topology::builder()
        .listen("net", listener)
        .sink("out", sink)
        .build()
        .run(GraphConfig {
            chunk_size: 1024,
            decode_threads: Some(WORKERS),
            ..Default::default()
        })
        .unwrap();
    for sender in senders {
        sender.join().unwrap();
    }
    supervisor.join().unwrap();
    let peak_threads = census.join().unwrap();

    // Exactly-once delivery, per client and in total.
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(report.events_in, total, "merge lost or duplicated events");
    assert_eq!(report.merge_dropped, 0);
    let counts = counts.lock().unwrap();
    for (x, &n) in counts.iter().enumerate().take(CLIENTS) {
        assert_eq!(n, PER_CLIENT as u64, "client {x} was not delivered exactly once");
    }

    // The thread budget held: W codec threads, never one per client.
    if cfg!(target_os = "linux") {
        assert!(peak_threads > 0, "decode plane threads never observed");
        assert!(
            peak_threads <= WORKERS,
            "decode thread census peaked at {peak_threads}, budget {WORKERS}"
        );
    }
    assert_eq!(report.decode_workers, WORKERS as u64);
    assert!(report.decode_jobs > 0, "no jobs reached the plane");
}
