//! Convert one recording through every supported format and compare
//! wire sizes — the practical face of the Table 1 "file support" column.
//!
//! ```sh
//! cargo run --release --example file_convert [-- input.aedat]
//! ```
//!
//! With no argument, converts a synthetic 500 ms recording. Every
//! conversion is verified lossless (except SPIF text notes where
//! documented).

use aestream::bench::Table;
use aestream::camera;
use aestream::formats::{EventCodec, Format};

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1);
    let (events, res, origin) = match arg {
        Some(path) => {
            let p = std::path::PathBuf::from(path);
            let (events, res, fmt) = aestream::formats::read_events_auto(&p)?;
            (events, res, format!("{} ({fmt})", p.display()))
        }
        None => {
            let events = camera::paper_recording(500_000, 11);
            (events, aestream::aer::Resolution::DAVIS_346, "synthetic 500 ms".into())
        }
    };
    println!("input: {origin} — {} events @ {res}\n", events.len());

    let mut table =
        Table::new(&["format", "bytes", "bytes/event", "vs raw", "lossless"]);
    let raw_size = {
        let mut buf = Vec::new();
        Format::Raw.codec().encode(&events, res, &mut buf)?;
        buf.len()
    };
    for format in Format::ALL {
        let codec = format.codec();
        let mut buf = Vec::new();
        codec.encode(&events, res, &mut buf)?;
        let (decoded, _) = codec.decode(&mut &buf[..])?;
        let lossless = decoded == events;
        table.row(&[
            format.to_string(),
            buf.len().to_string(),
            format!("{:.2}", buf.len() as f64 / events.len().max(1) as f64),
            format!("{:.2}×", buf.len() as f64 / raw_size as f64),
            if lossless { "yes".into() } else { "NO".into() },
        ]);
        anyhow::ensure!(lossless, "{format} round-trip failed");
    }
    println!("{}", table.render());
    println!("note: EVT3's 16-bit vectorized words win on structured scenes;");
    println!("      text/CSV is for shell pipelines, not storage.");
    Ok(())
}
