//! Declarative topology graphs: the ROADMAP's **multi-device fan-out**.
//!
//! Two synthetic cameras fuse into one timestamp-ordered stream, share
//! a denoise chain, then *split into two independent branches* — each
//! with its own filter chain and its own sink. With built device
//! artifacts (`make artifacts`), the branches terminate in two separate
//! `DetectorSession`s (ON events to one detector, OFF to the other);
//! without them, the example falls back to two frame binners so it
//! always runs.
//!
//! Run: `cargo run --release --example graph_topology`

use aestream::aer::Resolution;
use aestream::camera::CameraConfig;
use aestream::coordinator::SessionSink;
use aestream::pipeline::{ops, PipelineSpec, StageSpec};
use aestream::runtime::Device;
use aestream::stream::{
    CameraSource, FrameSink, FusionLayout, GraphConfig, RoutePolicy, StreamReport, Topology,
    TopologyBuilder,
};

/// The shared part of the graph: two cameras → merge → denoise chain →
/// polarity router. Each caller attaches its own pair of branches.
fn trunk<'a>() -> TopologyBuilder<'a> {
    Topology::builder()
        .source("cam0", CameraSource::new(CameraConfig::default(), 200_000))
        .source("cam1", CameraSource::new(CameraConfig::default(), 200_000))
        .merge_with_layout("fuse", &["cam0", "cam1"], FusionLayout::Overlay)
        .stages(
            "denoise",
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| {
                    ops::BackgroundActivityFilter::new(res, 2000)
                })),
        )
        .route("split", RoutePolicy::Polarity)
}

fn branch_chain(period_us: u64) -> PipelineSpec {
    PipelineSpec::new()
        .then(StageSpec::new(move |res: Resolution| ops::RefractoryFilter::new(res, period_us)))
}

fn print_report(report: &StreamReport) {
    println!(
        "fused {} events ({} out) on {}x{} in {:?} — {} frames",
        report.events_in,
        report.events_out,
        report.resolution.width,
        report.resolution.height,
        report.wall,
        report.frames,
    );
    for node in &report.sources {
        println!("  in  {}: {} events / {} batches", node.name, node.events, node.batches);
    }
    for node in &report.stages {
        println!("  stage {}: {} in / {} dropped", node.name, node.events, node.dropped);
    }
    for node in &report.sinks {
        println!(
            "  out {}: {} events / {} batches, {} frames",
            node.name, node.events, node.batches, node.frames
        );
    }
}

fn main() -> anyhow::Result<()> {
    let config = GraphConfig::default();
    match Device::open_default() {
        Ok(device) => {
            // ON events feed one detector session, OFF events the
            // other — two devices consuming one fused sensor stream.
            let report = trunk()
                .stages("on-chain", branch_chain(100))
                .sink("det-on", SessionSink::sparse(&device)?)
                .after("split")
                .stages("off-chain", branch_chain(200))
                .sink("det-off", SessionSink::sparse(&device)?)
                .build()
                .run(config)?;
            print_report(&report);
        }
        Err(e) => {
            eprintln!("artifacts not built ({e}); using frame binners instead");
            let report = trunk()
                .stages("on-chain", branch_chain(100))
                .sink("frames-on", FrameSink::new(Resolution::DAVIS_346, 10_000))
                .after("split")
                .stages("off-chain", branch_chain(200))
                .sink("frames-off", FrameSink::new(Resolution::DAVIS_346, 10_000))
                .build()
                .run(config)?;
            print_report(&report);
        }
    }
    Ok(())
}
