//! Closed-loop neuromorphic tracking — the paper's §6 future-work demo.
//!
//! ```sh
//! make artifacts && cargo run --release --example closed_loop
//! ```
//!
//! A rotating-dot scene streams through the synthetic camera into the
//! AOT-compiled LIF+conv edge detector on the device; the edge map's
//! activity centroid feeds a proportional controller that pans a
//! simulated actuator to keep the target on the crosshair — events in,
//! commands out, fully in the loop:
//!
//! ```text
//! scene ─▶ camera ─▶ framer ─▶ edge detector (XLA) ─▶ centroid
//!   ▲                                                    │
//!   └───────── pan actuator ◀── P controller ◀───────────┘
//! ```

use aestream::aer::Resolution;
use aestream::camera::{CameraConfig, Scene, SyntheticCamera};
use aestream::control::{centroid, PController, PanActuator};
use aestream::pipeline::framer::Framer;
use aestream::runtime::{DetectorSession, Device, TransferMode};

fn main() -> anyhow::Result<()> {
    let device = Device::open_default()?;
    let m = device.manifest();
    let res = Resolution::new(m.width as u16, m.height as u16);
    let mut session = DetectorSession::new(&device, TransferMode::Sparse)?;

    let controller = PController::new(8.0, 400.0);
    let mut actuator = PanActuator::new(400.0);

    // The target orbits the scene centre; the "camera" view is shifted
    // by the actuator's pan, so good control keeps the apparent target
    // near the crosshair.
    let window_us = 2_000u64;
    let mut errors = Vec::new();
    println!("step  pan(px)  apparent-err(px)  activity");
    for step in 0..120u64 {
        // Render the scene as seen from the current pan position: the
        // orbit centre shifts opposite to the pan.
        let mut camera = SyntheticCamera::new(CameraConfig {
            resolution: res,
            scene: Scene::RotatingDot {
                radius_px: 60.0,
                period_s: 1.2,
                dot_radius_px: 9.0,
            },
            noise_rate_hz: 1.0,
            frame_interval_us: window_us,
            seed: 1000 + step,
        });
        // Advance the simulated clock to this step's window so the dot
        // is at the right orbital phase.
        let mut events = Vec::new();
        let mut t = 0u64;
        while t < (step + 1) * window_us {
            let burst = camera.step();
            if t >= step * window_us {
                events.extend(burst);
            }
            t = camera.now_us();
        }
        // Apply the pan: shift apparent x by the actuator position.
        let pan = actuator.position;
        let events: Vec<_> = events
            .into_iter()
            .filter_map(|mut ev| {
                let x = ev.x as f32 - pan;
                if x < 0.0 || x >= res.width as f32 {
                    return None;
                }
                ev.x = x as u16;
                Some(ev)
            })
            .collect();

        // One frame window through the device edge detector.
        let frames = Framer::frames_of(res, window_us, &events);
        let Some(frame) = frames.last() else { continue };
        let out = session.step_sparse(
            &events[events.len().saturating_sub(session.max_events())..],
        )?;
        let _ = frame;

        // Close the loop on the edge map.
        if let Some((cx, _cy)) = centroid(&out.edges, res) {
            let err = cx - res.width as f32 / 2.0;
            let cmd = controller.command(err);
            actuator.apply(cmd, window_us);
            errors.push(err.abs());
            if step % 12 == 0 {
                println!(
                    "{step:>4}  {:>7.1}  {:>16.1}  {:>8.0}",
                    actuator.position,
                    err,
                    out.edges.iter().map(|v| v.abs()).sum::<f32>()
                );
            }
        }
    }

    let early = errors.iter().take(10).sum::<f32>() / errors.len().min(10).max(1) as f32;
    let late_n = errors.len().saturating_sub(10);
    let late = errors.iter().skip(late_n).sum::<f32>() / errors.len().min(10).max(1) as f32;
    println!("\nmean |error|: first 10 windows {early:.1} px → last 10 windows {late:.1} px");
    println!("commands issued: {}", actuator.commands);
    anyhow::ensure!(actuator.commands > 50, "loop never engaged");
    println!("closed loop OK — events in, actuator commands out, no Python in the path");
    Ok(())
}
