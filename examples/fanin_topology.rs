//! Multi-sensor fusion topology (paper §6): two synthetic cameras fan
//! in through the streaming timestamp-ordered merge — each on its own
//! OS thread — share one pipeline, and fan out to a frame binner plus a
//! counting sink.
//!
//! Run: `cargo run --release --example fanin_topology`

use aestream::camera::CameraConfig;
use aestream::coordinator::{
    run_topology, RoutePolicy, Sink, Source, StreamConfig, TopologyOptions,
};
use aestream::pipeline::PipelineSpec;

fn main() -> anyhow::Result<()> {
    let sources = vec![
        Source::Synthetic { config: CameraConfig::default(), duration_us: 100_000 }.into(),
        Source::Synthetic { config: CameraConfig::default(), duration_us: 100_000 }.into(),
    ];
    // Broadcast: every sink sees the fused stream. Try
    // `RoutePolicy::Stripes` to shard the canvas across sinks instead.
    let sinks = vec![Sink::Frames { window_us: 10_000 }, Sink::Null];

    let report = run_topology(
        sources,
        PipelineSpec::new(),
        sinks,
        TopologyOptions {
            config: StreamConfig::default(),
            source_threads: true, // one OS thread per camera
            route: RoutePolicy::Broadcast,
            ..Default::default()
        },
    )?;

    println!(
        "fused {} events onto a {}x{} canvas in {:?} ({} frames)",
        report.events_in,
        report.resolution.width,
        report.resolution.height,
        report.wall,
        report.frames,
    );
    for node in &report.sources {
        println!(
            "  in  {}: {} events / {} batches ({} backpressure waits)",
            node.name, node.events, node.batches, node.backpressure_waits
        );
    }
    println!(
        "  merge: peak {} events buffered, {} dropped",
        report.merge_peak_buffered, report.merge_dropped
    );
    for node in &report.sinks {
        println!(
            "  out {}: {} events / {} batches, {} frames",
            node.name, node.events, node.batches, node.frames
        );
    }
    Ok(())
}
