//! END-TO-END DRIVER — the paper's §5 use case, all layers composed.
//!
//! ```sh
//! make artifacts && cargo run --release --example edge_detection -- \
//!     [duration_ms] [time_scale]
//! ```
//!
//! 1. Synthesizes the 346×260 recording (paper: 24.8 s / 90 Mev from a
//!    DAVIS346; default here: 2 s at the same event rate — pass
//!    `24800 1` for the full-scale run).
//! 2. Loads the AOT-compiled LIF+conv edge detector (JAX → HLO text →
//!    PJRT) and runs **all four Fig. 4 scenarios**:
//!    threads/coroutines × dense/sparse transfer.
//! 3. Verifies device numerics against the pure-Rust `snn::EdgeDetector`
//!    oracle on a stream prefix.
//! 4. Prints the Fig. 4(B) (HtoD copy) and Fig. 4(C) (frames) tables.
//!
//! Results are recorded in EXPERIMENTS.md.

use aestream::aer::Resolution;
use aestream::bench::{fmt_rate, Table};
use aestream::camera;
use aestream::coordinator::{run_scenario, ScenarioConfig};
use aestream::pipeline::framer::Framer;
use aestream::runtime::{DetectorSession, Device, TransferMode};
use aestream::snn::EdgeDetector;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let duration_ms: u64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(2000);
    let time_scale: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1.0);

    // ------------------------------------------------------ recording
    eprintln!("[1/4] synthesizing {duration_ms} ms recording (DAVIS346 geometry)…");
    let recording = camera::paper_recording(duration_ms * 1000, 42);
    let rate = recording.len() as f64 / (duration_ms as f64 / 1e3);
    eprintln!(
        "      {} events ({}) — paper's recording ran ~3.6 Mev/s",
        recording.len(),
        fmt_rate(rate, "ev/s")
    );

    // --------------------------------------------------------- device
    eprintln!("[2/4] loading AOT artifacts on PJRT ({} modules)…", 4);
    let device = Device::open_default()?;
    eprintln!("      platform: {}", device.platform());

    // --------------------------------------------------- verification
    eprintln!("[3/4] verifying device numerics against the Rust oracle…");
    let m = device.manifest();
    let res = Resolution::new(m.width as u16, m.height as u16);
    let frames = Framer::frames_of(res, 1000, &recording);
    let mut session = DetectorSession::new(&device, TransferMode::Dense)?;
    let mut oracle = EdgeDetector::new(res);
    let mut worst = 0f32;
    for frame in frames.iter().take(10) {
        let out = session.step_dense(&frame.data)?;
        let (_, edges) = oracle.step_full(&frame.data);
        for (a, b) in out.edges.iter().zip(&edges) {
            worst = worst.max((a - b).abs());
        }
    }
    anyhow::ensure!(worst < 1e-4, "device/oracle divergence: {worst}");
    eprintln!("      OK — max |Δedge| over 10 frames: {worst:.2e}");

    // ------------------------------------------------------ scenarios
    eprintln!("[4/4] running the four Fig. 4 scenarios (time_scale={time_scale})…\n");
    let mut fig4b = Table::new(&[
        "scenario", "HtoD ms", "HtoD % runtime", "HtoD MB", "HtoD ops", "per-frame B",
    ]);
    let mut fig4c = Table::new(&["scenario", "frames", "fps", "events", "dropped"]);
    let mut reports = Vec::new();
    for cfg in ScenarioConfig::paper_four(time_scale) {
        let r = run_scenario(&device, &recording, &cfg)?;
        fig4b.row(&[
            r.label.clone(),
            format!("{:.2}", r.stats.htod_ns as f64 / 1e6),
            format!("{:.3}", r.htod_percent()),
            format!("{:.2}", r.stats.htod_bytes as f64 / 1e6),
            r.stats.htod_ops.to_string(),
            format!("{}", r.stats.htod_bytes / r.frames.max(1)),
        ]);
        fig4c.row(&[
            r.label.clone(),
            r.frames.to_string(),
            format!("{:.0}", r.fps()),
            r.events.to_string(),
            r.dropped.to_string(),
        ]);
        reports.push(r);
    }

    println!("── Fig. 4(B): host→device copy cost ───────────────────────");
    println!("{}", fig4b.render());
    println!("── Fig. 4(C): frames through the edge detector ────────────");
    println!("{}", fig4c.render());

    // ------------------------------------------------------ headlines
    let dense = &reports[0]; // threads+dense (conventional baseline)
    let best = &reports[3]; // coro+sparse   (full AEStream)
    let byte_ratio = dense.stats.htod_bytes as f64 / reports[2].stats.htod_bytes.max(1) as f64
        * (reports[2].frames as f64 / dense.frames.max(1) as f64);
    println!("── headline vs paper ───────────────────────────────────────");
    println!(
        "frames: coro+sparse/threads+dense = {:.2}× (paper: ~1.3×)",
        best.frames as f64 / dense.frames.max(1) as f64
    );
    println!(
        "per-frame HtoD bytes: dense/sparse = {byte_ratio:.1}× fewer for sparse (paper: ≥5×)"
    );
    Ok(())
}
