//! Sharded stage graph: two synthetic cameras fan in onto one canvas,
//! flow through a denoise stage running as four stripe-shard topology
//! nodes (ghost events keep its 8-neighbourhood state exact at stripe
//! boundaries), and fan out to a frame binner plus a counting sink —
//! with output byte-identical to the serial pipeline.
//!
//! Run: `cargo run --release --example sharded_pipeline`

use aestream::aer::Resolution;
use aestream::camera::CameraConfig;
use aestream::coordinator::{
    run_topology, RoutePolicy, Sink, Source, StreamConfig, TopologyOptions,
};
use aestream::pipeline::{ops, PipelineSpec, StageSpec};

fn main() -> anyhow::Result<()> {
    let sources = vec![
        Source::Synthetic { config: CameraConfig::default(), duration_us: 100_000 }.into(),
        Source::Synthetic { config: CameraConfig::default(), duration_us: 100_000 }.into(),
    ];
    let sinks = vec![Sink::Frames { window_us: 10_000 }, Sink::Null];

    // The spec defers geometry: the denoise filter is built for the
    // fused side-by-side canvas the *opened* sources report, and each
    // shard worker gets its own state copy for its pixel stripe.
    let spec = PipelineSpec::new()
        .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100)))
        .then(StageSpec::new(|res: Resolution| ops::BackgroundActivityFilter::new(res, 2000)));

    let report = run_topology(
        sources,
        spec,
        sinks,
        TopologyOptions {
            config: StreamConfig::default(),
            source_threads: true, // one OS thread per camera
            route: RoutePolicy::Broadcast,
            shards: 4,           // each shardable stage → 4 stripe nodes
            shard_threads: true, // one OS thread per shard worker
            ..Default::default()
        },
    )?;

    println!(
        "fused {} events, kept {} after the sharded chain, on a {}x{} canvas in {:?}",
        report.events_in,
        report.events_out,
        report.resolution.width,
        report.resolution.height,
        report.wall,
    );
    for node in &report.stages {
        println!(
            "  stage {}: {} in / {} dropped across {} shards (skew {:.2})",
            node.name,
            node.events,
            node.dropped,
            node.shard_events.len().max(1),
            node.shard_skew(),
        );
    }
    for node in &report.sinks {
        println!("  out {}: {} events, {} frames", node.name, node.events, node.frames);
    }
    Ok(())
}
