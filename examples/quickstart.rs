//! Quickstart: generate events, compose a pipeline, count what survives.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Mirrors the paper's Fig. 2 composition idea in ~30 lines of library
//! use: a synthetic DAVIS346 camera streams through a denoise →
//! refractory → crop chain into frame bins, all on the coroutine
//! engine's per-event path.

use aestream::aer::Resolution;
use aestream::bench::fmt_rate;
use aestream::camera::{CameraConfig, Scene, SyntheticCamera};
use aestream::metrics::Stopwatch;
use aestream::pipeline::framer::Framer;
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;

fn main() {
    let res = Resolution::DAVIS_346;

    // 1. A synthetic event camera (no hardware in this repo — see
    //    DESIGN.md §Substitutions): a bar sweeping over the sensor.
    let mut camera = SyntheticCamera::new(CameraConfig {
        resolution: res,
        scene: Scene::MovingBar { speed_px_per_s: 250.0, thickness_px: 6 },
        noise_rate_hz: 5.0,
        frame_interval_us: 1000,
        seed: 7,
    });
    let recording = camera.record(1_000_000); // one simulated second
    println!("recorded {} events in 1 s of simulated time", recording.len());

    // 2. Compose a pipeline, the paper's uniform-signature functions.
    let mut pipeline = Pipeline::new()
        .then(ops::BackgroundActivityFilter::new(res, 10_000))
        .then(ops::RefractoryFilter::new(res, 200))
        .then(ops::RoiCrop::new(0, 0, 346, 260));
    println!("pipeline: {}", pipeline.describe());

    // 3. Run it and bin the survivors into 1 ms frames.
    let sw = Stopwatch::start();
    let clean = pipeline.process(&recording);
    let frames = Framer::frames_of(res, 1000, &clean);
    let elapsed = sw.elapsed();

    let kept = 100.0 * clean.len() as f64 / recording.len() as f64;
    println!(
        "kept {} events ({kept:.1}%), binned into {} frames in {elapsed:?} ({})",
        clean.len(),
        frames.len(),
        fmt_rate(recording.len() as f64 / elapsed.as_secs_f64(), "ev/s"),
    );

    // 4. Where was the bar? The densest frame tells us.
    if let Some(busiest) = frames.iter().max_by_key(|f| f.event_count) {
        println!(
            "busiest window [{} µs, {} µs): {} events, |frame|₁ = {:.0}",
            busiest.t_start,
            busiest.t_end,
            busiest.event_count,
            busiest.l1()
        );
    }
}
