//! Adaptive runtime on a skewed workload: a synthetic hotspot stream
//! (90% of events in the left eighth of the canvas) flows through a
//! refractory stage sharded over four stripe workers. The `skew`
//! controller samples the live per-shard histograms every 32 batches
//! and re-cuts the stripe boundaries toward balance; the `chunk`
//! controller AIMD-tunes the batch size against edge backpressure.
//! Output is byte-identical to the serial pipeline throughout — only
//! the work placement changes.
//!
//! Run: `cargo run --release --example adaptive_pipeline`

use aestream::aer::Resolution;
use aestream::coordinator::{
    run_topology, AdaptiveConfig, ControllerKind, Sink, Source, StreamConfig, TopologyOptions,
};
use aestream::pipeline::{ops, PipelineSpec, StageSpec};
use aestream::testutil::hotspot_events_seeded;

fn main() -> anyhow::Result<()> {
    let res = Resolution::new(346, 260);
    let events = hotspot_events_seeded(2_000_000, res.width, res.height, 0xADA);

    let spec = PipelineSpec::new()
        .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 3)));

    let report = run_topology(
        vec![Source::Memory(events, res).into()],
        spec,
        vec![Sink::Null],
        TopologyOptions {
            config: StreamConfig { chunk_size: 4096, ..Default::default() },
            shards: 4,
            adaptive: Some(
                AdaptiveConfig::new(vec![ControllerKind::Skew, ControllerKind::Chunk])
                    .with_epoch(32),
            ),
            ..Default::default()
        },
    )?;

    let stage = &report.stages[0];
    println!(
        "processed {} events in {:?} — final shard skew {:.2} over {} shards \
         (1.0 = balanced; the static uniform cut sits near 3.6 on this stream)",
        report.events_in,
        report.wall,
        stage.shard_skew(),
        stage.shard_events.len(),
    );
    let adaptive = report.adaptive.expect("adaptive history");
    println!(
        "adaptive: {} epochs, {} re-cuts, {} chunk changes, final chunk {}",
        adaptive.epochs,
        adaptive.recuts.len(),
        adaptive.chunk_changes.len(),
        adaptive.final_chunk,
    );
    for recut in &adaptive.recuts {
        println!(
            "  epoch {:>3}: stage {} skew {:.2} → {:.2}, stripes end at {:?}",
            recut.epoch, recut.stage, recut.skew_before, recut.skew_after, recut.bounds,
        );
    }
    for change in &adaptive.chunk_changes {
        println!("  epoch {:>3}: chunk {} → {}", change.epoch, change.from, change.to);
    }
    Ok(())
}
