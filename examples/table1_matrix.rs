//! Regenerate Table 1 of the paper: the library I/O feature matrix.
//!
//! ```sh
//! cargo run --release --example table1_matrix
//! ```
//!
//! The survey rows are transcribed from the paper; this library's row is
//! derived from the compiled-in capabilities (see
//! `pipeline::registry::our_row` and its tests, which assert each claim
//! against the actual modules).

fn main() {
    println!("Table 1 — open-source AER library comparison (paper + this repo)\n");
    print!("{}", aestream::pipeline::registry::render_table());
    println!("\nIcons: GPU = device/tensor sink, CAM = camera input,");
    println!("       FILE = native file I/O, NET = network streaming.");
    println!("This repo's GPU column is the XLA/PJRT device runtime");
    println!("(the paper's CUDA path, adapted per DESIGN.md §Hardware-Adaptation).");
}
