//! Incremental streaming quickstart: file → filters → file with
//! O(chunk) memory, on the coroutine driver.
//!
//! ```sh
//! cargo run --release --example streaming_pipeline
//! ```
//!
//! Writes a synthetic recording to disk, then streams it back through
//! a denoise → polarity chain into a CSV file *without ever holding the
//! recording in memory*: the chunked decoder feeds bounded batches
//! through a rendezvous channel to the pipeline/sink coroutine. The
//! report's `peak_in_flight` counter proves the bound.

use aestream::aer::Resolution;
use aestream::bench::fmt_rate;
use aestream::camera;
use aestream::coordinator::{run_stream, run_stream_with, Sink, Source, StreamConfig};
use aestream::formats::Format;
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("aestream-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let recording_path = dir.join("recording.aedat");
    let output_path = dir.join("filtered.csv");
    let res = Resolution::DAVIS_346;

    // 1. Produce a half-second recording straight to disk: the camera
    //    is itself an EventSource, so nothing is collected in RAM.
    let report = run_stream(
        Source::Synthetic { config: camera::CameraConfig::default(), duration_us: 500_000 },
        Pipeline::new(),
        Sink::File(recording_path.clone(), Format::Aedat),
    )?;
    println!(
        "recorded {} events to {} ({} batches, peak {} in flight)",
        report.events_in,
        recording_path.display(),
        report.batches,
        report.peak_in_flight,
    );

    // 2. Stream it back through a filter chain into CSV. chunk=2048
    //    bounds memory; the coroutine driver overlaps decode with
    //    filtering + encode.
    let config = StreamConfig { chunk_size: 2048, ..Default::default() };
    let report = run_stream_with(
        Source::file(recording_path),
        Pipeline::new()
            .then(ops::BackgroundActivityFilter::new(res, 10_000))
            .then(ops::PolarityFilter::keep(aestream::aer::Polarity::On)),
        Sink::File(output_path.clone(), Format::Text),
        config,
    )?;
    println!(
        "filtered {} → {} events into {} in {:?} ({})",
        report.events_in,
        report.events_out,
        output_path.display(),
        report.wall,
        fmt_rate(report.throughput(), "ev/s"),
    );
    println!(
        "peak in-flight {} events (≤ chunk {}), {} backpressure waits — the \
         stream was never materialized",
        report.peak_in_flight, config.chunk_size, report.backpressure_waits,
    );
    anyhow::ensure!(report.peak_in_flight <= config.chunk_size, "memory bound violated");

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
