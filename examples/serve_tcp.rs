//! Serving events over the network: a `tcp-listen` ingest endpoint
//! feeding a sharded refractory filter whose output fans out to TCP
//! subscribers.
//!
//! Eight simulated cameras connect over loopback and stream SPIF words;
//! each becomes its own merge lane behind an AIMD-tuned credit window,
//! so memory stays bounded by `clients × window` no matter how fast the
//! senders push. A downstream consumer subscribes to the filtered
//! stream and counts what it receives. The CLI spells the same graph
//!
//! ```text
//! aestream input tcp-listen 0.0.0.0:7777 --geometry 346x260 \
//!          filter refractory 1000 output subscribe 0.0.0.0:7778 \
//!          --adaptive client-window --report-json -
//! ```
//!
//! Run: `cargo run --release --example serve_tcp`

use std::io::{Read, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use aestream::aer::Resolution;
use aestream::bench::fmt_rate;
use aestream::net::spif;
use aestream::pipeline::{ops, PipelineSpec, StageSpec};
use aestream::serve::{ListenerConfig, ListenerSource, SubscribeSink};
use aestream::stream::{AdaptiveConfig, ControllerKind, GraphConfig, StageOptions, Topology};
use aestream::testutil::synthetic_events_seeded;

const CLIENTS: usize = 8;
const PER_CLIENT: usize = 250_000;

fn main() -> anyhow::Result<()> {
    let res = Resolution::new(346, 260);

    let listener = ListenerSource::bind_tcp(
        "127.0.0.1:0",
        ListenerConfig::new(res).window(1024).max_clients(64),
    )?;
    let ingest_addr = listener.local_addr();
    let hub = listener.hub();

    let subscribe = SubscribeSink::bind("127.0.0.1:0")?;
    let egress_addr = subscribe.local_addr();
    println!("ingest (SPIF over TCP): {ingest_addr}");
    println!("egress (subscribe):     {egress_addr}");

    // One downstream consumer: counts the words it receives until the
    // sink closes its socket at shutdown.
    let consumer = thread::spawn(move || {
        let mut stream = TcpStream::connect(egress_addr).unwrap();
        let mut words = 0u64;
        let mut buf = [0u8; 16 * 1024];
        loop {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => words += (n / 4) as u64,
            }
        }
        words
    });

    // Eight simulated cameras stream SPIF words over loopback, each on
    // its own connection (= its own dynamically attached merge lane).
    let senders: Vec<_> = (0..CLIENTS)
        .map(|i| {
            thread::spawn(move || {
                let events =
                    synthetic_events_seeded(PER_CLIENT, res.width, res.height, 0xCAFE + i as u64);
                let mut bytes = Vec::with_capacity(events.len() * 4);
                for ev in &events {
                    bytes.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
                }
                let mut stream = TcpStream::connect(ingest_addr).unwrap();
                for chunk in bytes.chunks(16 * 1024) {
                    stream.write_all(chunk).unwrap();
                }
            })
        })
        .collect();

    // Close the door once every client has come and gone — a stand-in
    // for the operator's ctrl-C.
    let supervisor = {
        let hub = hub.clone();
        thread::spawn(move || {
            while hub.admitted() < CLIENTS as u64 || hub.active_clients() > 0 {
                thread::sleep(Duration::from_millis(1));
            }
            hub.shutdown();
        })
    };

    let spec = PipelineSpec::new()
        .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 1_000)));
    let report = Topology::builder()
        .listen("net", listener)
        .stages_with("refractory", spec, StageOptions { shards: 4, ..Default::default() })
        .sink("out", subscribe)
        .build()
        .run(GraphConfig {
            chunk_size: 4096,
            adaptive: Some(AdaptiveConfig::new(vec![ControllerKind::ClientWindow]).with_epoch(16)),
            ..Default::default()
        })?;

    for sender in senders {
        sender.join().unwrap();
    }
    supervisor.join().unwrap();
    let received = consumer.join().unwrap();

    println!(
        "served {} events from {CLIENTS} clients in {:?} ({})",
        report.events_in,
        report.wall,
        fmt_rate(report.throughput(), "ev/s"),
    );
    for node in report.sources.iter().filter(|n| n.name.starts_with("client:")) {
        println!(
            "  {}: {} events / {} batches, {} credit stalls",
            node.name, node.events, node.batches, node.backpressure_waits,
        );
    }
    if let Some(adaptive) = &report.adaptive {
        println!(
            "adaptive: {} epochs, {} per-client window changes",
            adaptive.epochs,
            adaptive.window_changes.len(),
        );
        for change in &adaptive.window_changes {
            println!(
                "  epoch {:>3}: {} window {} → {}",
                change.epoch, change.client, change.from, change.to,
            );
        }
    }
    println!(
        "subscriber received {received} words ({} events survived the filter)",
        report.events_out,
    );
    Ok(())
}
