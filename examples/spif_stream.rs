//! SPIF/UDP streaming demo — the paper's SpiNNaker path on loopback.
//!
//! ```sh
//! cargo run --release --example spif_stream
//! ```
//!
//! One thread plays a synthetic camera out as SPIF datagrams ("the
//! camera end"); the main thread receives, stamps arrival times, runs a
//! denoise filter, and bins frames ("the SpiNNaker end"). This is the
//! one-command camera→SpiNNaker bridge of the paper's §6, minus the
//! physical board (the wire protocol is the real SPIF layout).

use std::time::{Duration, Instant};

use aestream::aer::Resolution;
use aestream::bench::fmt_rate;
use aestream::camera::{CameraConfig, SyntheticCamera};
use aestream::net::{UdpEventReceiver, UdpEventSender};
use aestream::pipeline::framer::Framer;
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;

fn main() -> anyhow::Result<()> {
    let res = Resolution::DAVIS_346;
    let mut rx = UdpEventReceiver::bind("127.0.0.1:0")?;
    let addr = rx.local_addr()?;
    println!("receiver listening on {addr} (SPIF words over UDP)");

    // ------------------------------------------------- camera thread
    let sender = std::thread::spawn(move || -> anyhow::Result<(u64, u64)> {
        let mut camera = SyntheticCamera::new(CameraConfig::default());
        let mut tx = UdpEventSender::connect(addr)?;
        let t0 = Instant::now();
        // Stream 500 ms of camera time, pacing in real time per step.
        while camera.now_us() < 500_000 {
            let burst = camera.step();
            tx.send(&burst)?;
            let due = Duration::from_micros(camera.now_us());
            if let Some(wait) = due.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
        }
        Ok((tx.events_sent, tx.datagrams_sent))
    });

    // ----------------------------------------------- receiving end
    let mut pipeline = Pipeline::new().then(ops::BackgroundActivityFilter::new(res, 10_000));
    let mut framer = Framer::new(res, 1000);
    let mut frames = 0u64;
    let mut received = 0u64;
    let mut kept = 0u64;
    let deadline = Instant::now() + Duration::from_secs(3);
    let mut last_data = Instant::now();
    while Instant::now() < deadline && last_data.elapsed() < Duration::from_millis(300) {
        if let Some(batch) = rx.recv_batch()? {
            received += batch.len() as u64;
            last_data = Instant::now();
            for ev in batch {
                if let Some(ev) = pipeline.apply(ev) {
                    kept += 1;
                    frames += framer.push(&ev).len() as u64;
                }
            }
        }
    }
    frames += u64::from(framer.finish().is_some());

    let (sent, datagrams) = sender.join().expect("sender panicked")?;
    println!("sender:   {sent} events in {datagrams} datagrams");
    println!(
        "receiver: {received} events ({:.1}% of sent), {kept} after denoise, {frames} frames",
        100.0 * received as f64 / sent.max(1) as f64
    );
    println!(
        "loopback loss: {} events ({} — UDP is lossy by design; SPIF tolerates it)",
        sent - received.min(sent),
        fmt_rate((sent - received.min(sent)) as f64 / 0.5, "ev/s")
    );
    Ok(())
}
