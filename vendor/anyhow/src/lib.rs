//! Offline stand-in for the `anyhow` crate.
//!
//! This build environment has no registry access, so the repository
//! vendors the small subset of `anyhow`'s API it actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Semantics match upstream for this subset: errors carry a context
//! chain that `{:?}` renders as a "Caused by" list, and any
//! `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A dynamically typed error with a chain of context messages.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        items.into_iter()
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        &cur.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        fn nested(err: &(dyn std::error::Error + 'static)) -> Option<Box<Error>> {
            err.source()
                .map(|s| Box::new(Error { msg: s.to_string(), source: nested(s) }))
        }
        Error { msg: err.to_string(), source: nested(&err) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring upstream `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Attach a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let err = inner().unwrap_err();
        assert_eq!(err.to_string(), "disk on fire");
    }

    #[test]
    fn context_chains_and_renders() {
        let err: Result<()> = Err(io_err());
        let err = err.context("reading header").unwrap_err();
        assert_eq!(err.to_string(), "reading header");
        assert_eq!(err.root_cause(), "disk on fire");
        let debug = format!("{err:?}");
        assert!(debug.contains("Caused by:"), "{debug}");
        assert!(debug.contains("disk on fire"), "{debug}");
    }

    #[test]
    fn option_context_and_with_context() {
        let missing: Option<u32> = None;
        assert!(missing.context("absent").is_err());
        let present = Some(5).with_context(|| "unused").unwrap();
        assert_eq!(present, 5);
    }

    #[test]
    fn macros_compose() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(f(12).unwrap_err().to_string().contains("12"));
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
