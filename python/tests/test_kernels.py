"""Kernel-vs-oracle equivalence: Pallas kernels against ref.py.

Hypothesis sweeps shapes, counts and value ranges; every property pins
the Pallas output to the pure-jnp oracle with tight tolerances (the
kernels are float32 elementwise / integer scatter, so differences beyond
1e-6 indicate a real bug, not float noise).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import event_scatter, lif_step, ref
from compile.kernels.event_scatter import BLOCK_EVENTS

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ----------------------------------------------------------------- LIF

def _lif_case(h, w, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, size=(h, w)).astype(np.float32)
    v = rng.normal(0.0, 1.0, size=(h, w)).astype(np.float32)
    r = rng.integers(0, 5, size=(h, w)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(v), jnp.asarray(r)


@given(
    h=st.integers(min_value=1, max_value=96),
    w=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lif_matches_ref_over_shapes(h, w, seed):
    x, v, r = _lif_case(h, w, seed)
    s_k, v_k, r_k = lif_step(x, v, r)
    s_r, v_r, r_r = ref.lif_step_ref(x, v, r)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))


def test_lif_paper_geometry():
    x, v, r = _lif_case(260, 346, 7)
    s_k, v_k, r_k = lif_step(x, v, r)
    s_r, v_r, r_r = ref.lif_step_ref(x, v, r)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r_k), np.asarray(r_r))


def test_lif_spike_semantics():
    # One neuron above threshold, one below, one refractory.
    x = jnp.asarray([[2.0, 0.5, 2.0]], dtype=jnp.float32)
    v = jnp.zeros((1, 3), jnp.float32)
    r = jnp.asarray([[0.0, 0.0, 2.0]], dtype=jnp.float32)
    s, v2, r2 = lif_step(x, v, r)
    assert s.tolist() == [[1.0, 0.0, 0.0]]
    assert v2.tolist() == [[0.0, 0.5, 0.0]]  # reset / integrate / blocked
    assert r2.tolist() == [[3.0, 0.0, 1.0]]  # set / idle / count down


def test_lif_state_chain_matches_ref_over_time():
    # Multi-step chaining: state errors would compound and be caught.
    x, v, r = _lif_case(52, 64, 3)
    vk, rk = v, r
    vr, rr = v, r
    for _ in range(10):
        _, vk, rk = lif_step(x, vk, rk)
        _, vr, rr = ref.lif_step_ref(x, vr, rr)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))


# ------------------------------------------------------------- scatter

def _events_case(n_blocks, count, h, w, seed):
    rng = np.random.default_rng(seed)
    n = n_blocks * BLOCK_EVENTS
    ev = np.zeros((n, 3), dtype=np.int32)
    ev[:count, 0] = rng.integers(0, w, count)
    ev[:count, 1] = rng.integers(0, h, count)
    ev[:count, 2] = rng.integers(0, 2, count)
    # Sentinel padding: p < 0 marks a row as void; coordinates may be
    # garbage (the kernel must clamp, the sign mask must zero them).
    ev[count:, 0] = rng.integers(-5, w + 5, n - count)
    ev[count:, 1] = rng.integers(-5, h + 5, n - count)
    ev[count:, 2] = -rng.integers(1, 4, n - count)
    return jnp.asarray(ev)


@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    frac=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_scatter_matches_ref(n_blocks, frac, seed):
    h, w = 64, 80
    n = n_blocks * BLOCK_EVENTS
    count = int(frac * n)
    ev = _events_case(n_blocks, count, h, w, seed)
    got = event_scatter(ev, height=h, width=w)
    want = ref.event_scatter_ref(ev, h, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_scatter_paper_geometry_full_capacity():
    ev = _events_case(4, 4096, 260, 346, 11)
    got = event_scatter(ev, height=260, width=346)
    want = ref.event_scatter_ref(ev, 260, 346)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Conservation: sum of frame == sum of signs of valid rows.
    pol = np.asarray(ev[:, 2])
    signs = np.where(pol >= 0, 2 * pol - 1, 0)
    assert float(jnp.sum(got)) == float(signs.sum())


def test_scatter_all_padding_is_zero_frame():
    ev = _events_case(1, 0, 32, 32, 5)
    got = event_scatter(ev, height=32, width=32)
    assert float(jnp.abs(got).sum()) == 0.0


def test_scatter_repeated_pixel_accumulates():
    n = BLOCK_EVENTS
    ev = np.full((n, 3), -1, np.int32)  # all padding
    ev[:10] = [5, 7, 1]   # ten ON events at (5,7)
    ev[10:15] = [5, 7, 0]  # five OFF events at (5,7)
    got = event_scatter(jnp.asarray(ev), height=16, width=16)
    assert got[7, 5] == 5.0  # 10 - 5
    assert float(jnp.abs(got).sum()) == 5.0


def test_scatter_rejects_non_block_multiple():
    ev = jnp.zeros((100, 3), jnp.int32)
    with pytest.raises(ValueError):
        event_scatter(ev, height=8, width=8)


# ---------------------------------------------------------------- conv

def test_conv_ref_matches_manual_laplacian():
    img = np.zeros((5, 5), np.float32)
    img[2, 2] = 1.0
    out = np.asarray(ref.conv2d_3x3_ref(jnp.asarray(img), ref.LAPLACIAN_3X3))
    assert out[2, 2] == 4.0
    assert out[2, 1] == out[1, 2] == out[2, 3] == out[3, 2] == -1.0
    assert out[0, 0] == 0.0
