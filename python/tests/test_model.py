"""L2 model tests: dense/sparse step equivalence and export sanity.

The key property: running ``sparse_step`` on an event list must produce
*exactly* the same edges/state as binning on the host and running
``dense_step`` — that equivalence is what lets the Fig. 4 benchmark
attribute performance differences purely to the transfer strategy.
"""

import json
import os

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _random_events(count, seed):
    rng = np.random.default_rng(seed)
    ev = np.full((model.MAX_EVENTS, 3), -1, dtype=np.int32)  # sentinel pad
    ev[:count, 0] = rng.integers(0, model.WIDTH, count)
    ev[:count, 1] = rng.integers(0, model.HEIGHT, count)
    ev[:count, 2] = rng.integers(0, 2, count)
    return jnp.asarray(ev)


def _zero_state():
    z = jnp.zeros((model.HEIGHT, model.WIDTH), jnp.float32)
    return z, z


@given(
    count=st.integers(min_value=0, max_value=model.MAX_EVENTS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sparse_equals_dense_on_host_binned_frame(count, seed):
    ev = _random_events(count, seed)
    v, r = _zero_state()
    frame = ref.event_scatter_ref(ev, model.HEIGHT, model.WIDTH)
    e_d, s_d, v_d, r_d = model.dense_step(frame, v, r)
    e_s, s_s, v_s, r_s = model.sparse_step(ev, v, r)
    np.testing.assert_allclose(np.asarray(e_d), np.asarray(e_s), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))
    np.testing.assert_allclose(np.asarray(v_d), np.asarray(v_s), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r_d), np.asarray(r_s))


def test_state_persists_across_frames():
    # Subthreshold input twice: second step must spike (integration),
    # proving state actually carries.
    v, r = _zero_state()
    frame = jnp.full((model.HEIGHT, model.WIDTH), 0.6, jnp.float32)
    _, s1, v1, r1 = model.dense_step(frame, v, r)
    assert float(s1.sum()) == 0.0
    _, s2, _, _ = model.dense_step(frame, v1, r1)
    assert float(s2.sum()) == model.HEIGHT * model.WIDTH


def test_edges_zero_on_uniform_spikes_interior():
    # All pixels spike together -> Laplacian cancels in the interior.
    v, r = _zero_state()
    frame = jnp.full((model.HEIGHT, model.WIDTH), 2.0, jnp.float32)
    edges, spikes, _, _ = model.dense_step(frame, v, r)
    e = np.asarray(edges)
    assert np.abs(e[1:-1, 1:-1]).max() == 0.0
    assert np.abs(e[0, :]).max() > 0.0  # border sees zero padding


def test_example_args_cover_exports():
    for name in model.EXPORTS:
        args = model.example_args(name)
        assert len(args) >= 1


def test_manifest_matches_artifacts_if_present():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        import pytest

        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["height"] == model.HEIGHT
    assert manifest["width"] == model.WIDTH
    assert manifest["max_events"] == model.MAX_EVENTS
    for name, meta in manifest["modules"].items():
        path = os.path.join(art, meta["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as fh:
            text = fh.read()
        import hashlib

        assert hashlib.sha256(text.encode()).hexdigest() == meta["sha256"], (
            f"{name}: artifact out of date; run `make artifacts`"
        )


def test_shift_add_laplacian_matches_generic_conv():
    # The optimized L2 edge extraction must equal the generic-conv oracle
    # (EXPERIMENTS.md §Perf, L2 entry).
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(model.HEIGHT, model.WIDTH)).astype(np.float32))
    got = model.laplacian_shift_add(x)
    want = ref.conv2d_3x3_ref(x, ref.LAPLACIAN_3X3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_free_variants_match_full_steps():
    # The free-running exports must produce the same state trajectory as
    # the full exports, and their activity readout must equal sum(|edges|).
    ev = _random_events(2000, 17)
    v, r = _zero_state()
    e_full, _s, v_full, r_full = model.sparse_step(ev, v, r)
    act, v_free, r_free = model.sparse_step_free(ev, v, r)
    np.testing.assert_allclose(np.asarray(v_full), np.asarray(v_free), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r_full), np.asarray(r_free))
    np.testing.assert_allclose(
        float(act[0]), float(jnp.sum(jnp.abs(e_full))), rtol=1e-5
    )

    frame = ref.event_scatter_ref(ev, model.HEIGHT, model.WIDTH)
    e_full, _s, v_full, r_full = model.dense_step(frame, v, r)
    act, v_free, r_free = model.dense_step_free(frame, v, r)
    np.testing.assert_allclose(np.asarray(v_full), np.asarray(v_free), atol=1e-6)
    np.testing.assert_allclose(
        float(act[0]), float(jnp.sum(jnp.abs(e_full))), rtol=1e-5
    )


def test_aot_hlo_text_is_parseable_hlo():
    # The exporter's interchange format is HLO *text*; every export must
    # contain an HloModule header and an ENTRY computation (what the
    # Rust-side text parser requires).
    import jax
    from compile import aot

    for name, fn in model.EXPORTS.items():
        lowered = jax.jit(fn).lower(*model.example_args(name))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_export_is_idempotent(tmp_path):
    from compile import aot

    m1 = aot.export_all(str(tmp_path))
    m2 = aot.export_all(str(tmp_path))
    assert m1 == m2, "AOT export must be deterministic"
    assert set(m1["modules"]) == set(model.EXPORTS)
