"""Build-time compile package: JAX model (L2) + Pallas kernels (L1).

Nothing in this package runs on the request path. ``make artifacts``
invokes :mod:`compile.aot` once to lower the model to HLO text under
``artifacts/``; the Rust coordinator loads those artifacts via PJRT.
"""
