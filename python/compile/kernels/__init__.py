"""Layer-1 Pallas kernels (build-time only; lowered to HLO via aot.py).

Kernels:
  * ``event_scatter`` -- bin a padded sparse event list into a dense frame
    on-device (the paper's custom CUDA scatter kernel, re-thought for the
    XLA device; see DESIGN.md section Hardware-Adaptation).
  * ``lif_step`` -- tiled elementwise LIF-with-refractory state update.

Every kernel has a pure-jnp oracle in ``ref.py``; pytest + hypothesis
enforce equivalence before anything is exported.
"""

from .event_scatter import event_scatter  # noqa: F401
from .lif_step import lif_step  # noqa: F401
from . import ref  # noqa: F401
