"""Pure-jnp oracles for the Pallas kernels.

These are the *specification*: small, obviously-correct jax.numpy
implementations. They intentionally mirror the pure-Rust reference in
``rust/src/snn/`` (operation order included, for float agreement) and
are what the pytest + hypothesis suites compare the Pallas kernels
against.
"""

import jax.numpy as jnp

# LIF parameters -- keep in sync with rust/src/snn/lif.rs::LifParams::default().
DECAY = 0.9
THRESHOLD = 1.0
V_RESET = 0.0
REFRAC_STEPS = 3.0


def lif_step_ref(x, v, r):
    """One LIF-with-refractory step. All arrays share one shape.

    Args:
      x: input frame (f32).
      v: membrane voltage state (f32).
      r: remaining refractory steps (f32, integer-valued).

    Returns:
      (spikes, v_next, r_next), all f32 with the input shape.
    """
    integrating = r == 0.0
    v2 = v * DECAY + jnp.where(integrating, x, 0.0)
    spike = jnp.logical_and(integrating, v2 >= THRESHOLD)
    spikes = spike.astype(jnp.float32)
    v_next = jnp.where(spike, V_RESET, v2)
    r_next = jnp.where(spike, REFRAC_STEPS, jnp.maximum(r - 1.0, 0.0))
    return spikes, v_next, r_next


def event_scatter_ref(events, height, width):
    """Bin a padded event list into a dense signed-count frame.

    Args:
      events: i32[N, 3] rows of (x, y, p) with p in {0, 1} for real
        events; padding rows carry the sentinel p < 0 and must not
        contribute. (Sentinel padding keeps the sparse transfer a single
        host->device operation -- no separate count scalar.)
      height, width: frame geometry (static).

    Returns:
      f32[height, width] frame of sum(2p - 1) per pixel.
    """
    pol = events[:, 2]
    sign = jnp.where(pol >= 0, (2 * pol - 1).astype(jnp.float32), 0.0)
    # Clamp coordinates so padded/malformed rows cannot index out of
    # bounds (their contribution is zero anyway).
    x = jnp.clip(events[:, 0], 0, width - 1)
    y = jnp.clip(events[:, 1], 0, height - 1)
    frame = jnp.zeros((height, width), dtype=jnp.float32)
    return frame.at[y, x].add(sign)


def conv2d_3x3_ref(img, kernel):
    """'Same' 3x3 cross-correlation with zero padding over f32[H, W].

    Matches rust/src/snn/conv.rs::conv2d_3x3 and the lax.conv the model
    uses.
    """
    import jax

    lhs = img[None, None, :, :]
    rhs = kernel.reshape(1, 1, 3, 3)
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0, 0]


LAPLACIAN_3X3 = jnp.array(
    [[0.0, -1.0, 0.0], [-1.0, 4.0, -1.0], [0.0, -1.0, 0.0]], dtype=jnp.float32
)
