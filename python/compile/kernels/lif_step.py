"""Pallas kernel: tiled elementwise LIF-with-refractory update.

The VPU-friendly half of the edge detector: pure elementwise math over
the frame, tiled by rows so each grid step streams one
``(ROW_BLOCK, W)`` stripe of x/v/r through VMEM. Semantics match
``ref.lif_step_ref`` exactly (which in turn matches
rust/src/snn/lif.rs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _lif_kernel(x_ref, v_ref, r_ref, s_out, v_out, r_out):
    x = x_ref[...]
    v = v_ref[...]
    r = r_ref[...]
    integrating = r == 0.0
    v2 = v * ref.DECAY + jnp.where(integrating, x, 0.0)
    spike = jnp.logical_and(integrating, v2 >= ref.THRESHOLD)
    s_out[...] = spike.astype(jnp.float32)
    v_out[...] = jnp.where(spike, ref.V_RESET, v2)
    r_out[...] = jnp.where(spike, ref.REFRAC_STEPS, jnp.maximum(r - 1.0, 0.0))


def _row_block(height):
    """Largest row-block <= 64 that divides the frame height evenly."""
    for cand in range(min(64, height), 0, -1):
        if height % cand == 0:
            return cand
    return height


@functools.partial(jax.jit)
def lif_step(x, v, r):
    """One LIF step over f32[H, W] (x, v, r) -> (spikes, v', r')."""
    height, width = x.shape
    rb = _row_block(height)
    grid = height // rb
    spec = pl.BlockSpec((rb, width), lambda i: (i, 0))
    return pl.pallas_call(
        _lif_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((height, width), jnp.float32),
            jax.ShapeDtypeStruct((height, width), jnp.float32),
            jax.ShapeDtypeStruct((height, width), jnp.float32),
        ),
        grid=(grid,),
        in_specs=[spec, spec, spec],
        out_specs=(spec, spec, spec),
        interpret=True,
    )(x, v, r)
