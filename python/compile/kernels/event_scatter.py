"""Pallas kernel: scatter a padded sparse event list into a dense frame.

This is the paper's custom CUDA kernel (section 5, scenarios 3 and 4)
re-thought for the XLA device per DESIGN.md section Hardware-Adaptation:

* CUDA: a threadblock per event chunk, atomicAdd into a device-resident
  frame.
* Here: a Pallas grid over event *blocks* (``BLOCK_EVENTS`` rows per
  step); each grid step scatter-accumulates its block into the output
  frame block, which stays VMEM-resident across the whole grid (constant
  ``index_map``) -- the HBM <-> VMEM schedule the paper expressed with
  threadblocks is expressed with BlockSpecs. A 346x260 f32 frame is
  ~352 KiB, comfortably inside a TPU core's ~16 MiB VMEM.

The block-local accumulation uses a vectorized ``scatter-add`` over the
block rather than a per-event loop: on the interpret/CPU path this
lowers to a single native HLO Scatter per block (a per-event
``fori_loop`` of dynamic-update-slices measured ~40 us *per event* on
the CPU backend -- see EXPERIMENTS.md section Perf for the comparison);
on a real TPU the same structure maps to VPU gather/scatter within the
resident tile.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is
exactly what ``aot.py`` exports for the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Events per grid step. 1024 rows x 3 i32 = 12 KiB per block transfer.
BLOCK_EVENTS = 1024


def _scatter_kernel(ev_ref, o_ref):
    """One grid step: accumulate BLOCK_EVENTS (masked) events into o_ref."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    height, width = o_ref.shape

    ev = ev_ref[...]  # (BLOCK_EVENTS, 3) i32 values in registers/VMEM
    pol = ev[:, 2]
    # Padding rows carry the sentinel p < 0 and contribute 0.
    sign = jnp.where(pol >= 0, (2 * pol - 1).astype(jnp.float32), 0.0)
    # Clamp coordinates so padded/malformed rows cannot index out of
    # bounds (their contribution is zero anyway).
    x = jnp.clip(ev[:, 0], 0, width - 1)
    y = jnp.clip(ev[:, 1], 0, height - 1)
    block_frame = jnp.zeros((height, width), jnp.float32).at[y, x].add(sign)
    o_ref[...] += block_frame


@functools.partial(jax.jit, static_argnames=("height", "width"))
def event_scatter(events, *, height, width):
    """Bin ``events`` (i32[N, 3] of (x, y, p), padded) into f32[H, W].

    Padding rows carry the sentinel polarity ``p < 0`` and contribute
    nothing. N must be a multiple of BLOCK_EVENTS (aot.py pads the
    shape).
    """
    n = events.shape[0]
    if n % BLOCK_EVENTS != 0:
        raise ValueError(f"event count {n} not a multiple of {BLOCK_EVENTS}")
    grid = n // BLOCK_EVENTS
    return pl.pallas_call(
        _scatter_kernel,
        out_shape=jax.ShapeDtypeStruct((height, width), jnp.float32),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_EVENTS, 3), lambda i: (i, 0)),  # event block
        ],
        out_specs=pl.BlockSpec((height, width), lambda i: (0, 0)),  # resident
        interpret=True,
    )(events)
