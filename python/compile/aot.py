"""AOT exporter: lower the L2 model to HLO text for the Rust runtime.

HLO *text* (not ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the xla_extension 0.5.1 behind the ``xla``
crate rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Every exported function is lowered with ``return_tuple=True`` so the
Rust side unwraps one tuple per execution.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    """Lower every EXPORTS entry; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "height": model.HEIGHT,
        "width": model.WIDTH,
        "max_events": model.MAX_EVENTS,
        "modules": {},
    }
    for name, fn in model.EXPORTS.items():
        args = model.example_args(name)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name} for a in args
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    export_all(args.out_dir)


if __name__ == "__main__":
    main()
