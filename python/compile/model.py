"""Layer-2 JAX model: the spiking edge detector of the paper's section 5.

A LIF neuron layer with refractory term (the L1 Pallas ``lif_step``
kernel) followed by a regular 3x3 Laplacian convolution. Two step
functions correspond to the paper's two device-transfer strategies:

* :func:`dense_step` -- host builds the dense frame, device runs the
  detector (scenarios 1-2: full-tensor copy);
* :func:`sparse_step` -- host ships the *sparse* event list, the L1
  ``event_scatter`` Pallas kernel bins it on-device, then the detector
  runs (scenarios 3-4: sparse copy, the paper's custom CUDA kernels).

Both are state-carrying: ``(inputs, v, r) -> (edges, spikes, v', r')``;
the Rust runtime feeds v/r back each frame, so the network persists
across the stream without Python in the loop.
"""

import jax
import jax.numpy as jnp

from .kernels import event_scatter, lif_step
from .kernels import ref

# Paper use-case geometry (DAVIS346) and the per-frame event capacity.
HEIGHT = 260
WIDTH = 346
# Max events per frame window. The paper's recording averages ~3.6 Mev/s
# = ~3629 events per 1 ms window; 4096 gives headroom and is a multiple
# of the scatter kernel's 1024-event block.
MAX_EVENTS = 4096


def laplacian_shift_add(s):
    """Laplacian via shifted adds: ``4s - up - down - left - right``.

    Numerically identical (to f32 rounding) to the generic
    ``lax.conv_general_dilated`` with the LAPLACIAN_3X3 kernel, but ~59x
    faster on the CPU PJRT backend (5.31 ms -> 0.09 ms per 260x346
    frame; EXPERIMENTS.md section Perf, L2 entry). The generic-conv form
    remains in ``ref.conv2d_3x3_ref`` as the oracle; a pytest pins the
    two together.
    """
    up = jnp.pad(s[1:, :], ((0, 1), (0, 0)))
    down = jnp.pad(s[:-1, :], ((1, 0), (0, 0)))
    left = jnp.pad(s[:, 1:], ((0, 0), (0, 1)))
    right = jnp.pad(s[:, :-1], ((0, 0), (1, 0)))
    return 4.0 * s - up - down - left - right


def detector_core(frame, v, r):
    """LIF + Laplacian conv over a dense f32[H, W] frame."""
    spikes, v_next, r_next = lif_step(frame, v, r)
    edges = laplacian_shift_add(spikes)
    return edges, spikes, v_next, r_next


def dense_step(frame, v, r):
    """Dense-transfer step: host supplies the full f32[H, W] frame."""
    return detector_core(frame, v, r)


def sparse_step(events, v, r):
    """Sparse-transfer step: events i32[MAX_EVENTS, 3], sentinel-padded.

    The frame is built on-device by the Pallas scatter kernel; the host
    copies only ``MAX_EVENTS * 12`` bytes instead of ``H * W * 4``, in a
    single transfer operation (padding rows carry polarity -1).
    """
    frame = event_scatter(events, height=HEIGHT, width=WIDTH)
    return detector_core(frame, v, r)


def scatter_only(events):
    """Just the binning kernel (micro-bench + unit-verification module)."""
    return (event_scatter(events, height=HEIGHT, width=WIDTH),)


def lif_only(x, v, r):
    """Just the LIF kernel (micro-bench module)."""
    return lif_step(x, v, r)


def dense_step_free(frame, v, r):
    """Free-running dense step: edges are consumed on-device.

    The paper's benchmark loop never copies results back to the host --
    frames live and die on the GPU. Returning the full edge/spike maps
    through the PJRT tuple would haul H*W*8 bytes across the boundary
    every frame, so the free-running variant reduces the edge map to a
    scalar activity readout (|edges| summed; keeps the convolution from
    being dead-code-eliminated) and returns only the recycled state.
    EXPERIMENTS.md section Perf, L3 entry.
    """
    edges, _spikes, v_next, r_next = detector_core(frame, v, r)
    activity = jnp.sum(jnp.abs(edges)).reshape(1)
    return activity, v_next, r_next


def sparse_step_free(events, v, r):
    """Free-running sparse step (see dense_step_free)."""
    edges, _spikes, v_next, r_next = sparse_step(events, v, r)
    activity = jnp.sum(jnp.abs(edges)).reshape(1)
    return activity, v_next, r_next


def example_args(name):
    """ShapeDtypeStructs for lowering each exported function."""
    f32 = jnp.float32
    i32 = jnp.int32
    frame = jax.ShapeDtypeStruct((HEIGHT, WIDTH), f32)
    events = jax.ShapeDtypeStruct((MAX_EVENTS, 3), i32)
    return {
        "dense_step": (frame, frame, frame),
        "sparse_step": (events, frame, frame),
        "dense_step_free": (frame, frame, frame),
        "sparse_step_free": (events, frame, frame),
        "scatter_only": (events,),
        "lif_only": (frame, frame, frame),
    }[name]


EXPORTS = {
    "dense_step": dense_step,
    "sparse_step": sparse_step,
    "dense_step_free": dense_step_free,
    "sparse_step_free": sparse_step_free,
    "scatter_only": scatter_only,
    "lif_only": lif_only,
}
