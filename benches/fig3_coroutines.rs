//! Fig. 3 reproduction: coroutine vs thread throughput on the checksum
//! workload.
//!
//! Paper setup (§4.1): a single thread reads a RAM-cached event array;
//! the threaded contender hands fixed-size buffers (2^8, 2^10, 2^12) to
//! worker threads through a lock; the coroutine contender hands single
//! events through a cooperative channel; the baseline is a plain
//! function call. Every run's checksum is verified. The paper repeats
//! 128×; we use warmup+samples per point, scaled so the whole bench
//! stays minutes-scale on one core.
//!
//! Output: Fig. 3(A) runtimes per event count, and Fig. 3(B) relative
//! speedup of coroutines vs the mean/min/max thread runtime across
//! buffer sizes — the same series the paper plots.
//!
//! Run: `cargo bench --bench fig3_coroutines`

use aestream::aer::checksum::reference_checksum;
use aestream::bench::{fmt_duration, fmt_rate, measure, Table};
use aestream::engine::EngineKind;
use aestream::testutil::synthetic_events;

fn main() {
    // Smoke mode for CI: AESTREAM_BENCH_FAST=1 shrinks the sweep.
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let event_counts: &[usize] = if fast {
        &[1 << 14, 1 << 16]
    } else {
        &[1 << 14, 1 << 16, 1 << 18, 1 << 20]
    };
    let buffer_sizes = [1 << 8, 1 << 10, 1 << 12]; // paper's 2^8, 2^10, 2^12
    let worker_counts = [1usize, 2, 4];
    let samples = if fast { 3 } else { 10 };

    println!("Fig. 3 — coroutines vs threads (checksum workload, verified)\n");

    let mut fig3a = Table::new(&["events", "engine", "mean ± std", "min", "throughput"]);
    let mut fig3b = Table::new(&[
        "events",
        "vs mean-of-configs",
        "vs fastest config",
        "vs slowest config",
    ]);

    for &n in event_counts {
        let events = synthetic_events(n, 346, 260);
        let expected = reference_checksum(&events);
        let verify = |kind: EngineKind| {
            assert_eq!(kind.run_checksum(&events), expected, "{}: checksum", kind.label());
        };

        // --- baseline: no synchronization (dashed line in the paper).
        verify(EngineKind::Sync);
        let sync_stats = measure(2, samples, || {
            std::hint::black_box(EngineKind::Sync.run_checksum(&events));
        });
        fig3a.row(&[
            n.to_string(),
            "sync (baseline)".into(),
            sync_stats.display_mean(),
            fmt_duration(sync_stats.min_s),
            fmt_rate(sync_stats.throughput(n as u64), "ev/s"),
        ]);

        // --- coroutines: direct control transfer, per-event handoff.
        let coro = EngineKind::Coro;
        verify(coro);
        let coro_stats = measure(2, samples, || {
            std::hint::black_box(coro.run_checksum(&events));
        });
        fig3a.row(&[
            n.to_string(),
            coro.label(),
            coro_stats.display_mean(),
            fmt_duration(coro_stats.min_s),
            fmt_rate(coro_stats.throughput(n as u64), "ev/s"),
        ]);

        // --- threads: every (buffer, workers) combination.
        let mut thread_medians = Vec::new();
        for &buf in &buffer_sizes {
            for &workers in &worker_counts {
                let kind = EngineKind::Threaded { buffer_size: buf, workers };
                verify(kind);
                let stats = measure(1, samples, || {
                    std::hint::black_box(kind.run_checksum(&events));
                });
                fig3a.row(&[
                    n.to_string(),
                    kind.label(),
                    stats.display_mean(),
                    fmt_duration(stats.min_s),
                    fmt_rate(stats.throughput(n as u64), "ev/s"),
                ]);
                thread_medians.push(stats.median_s);
            }
        }

        // --- Fig. 3(B): relative speedup of coroutines vs threads.
        // Medians, not means: on the single-core testbed OS preemption
        // produces multi-ms outliers that would dominate a mean.
        let mean_t = thread_medians.iter().sum::<f64>() / thread_medians.len() as f64;
        let min_t = thread_medians.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_t = thread_medians.iter().cloned().fold(0.0, f64::max);
        fig3b.row(&[
            n.to_string(),
            format!("{:.2}×", mean_t / coro_stats.median_s),
            format!("{:.2}×", min_t / coro_stats.median_s),
            format!("{:.2}×", max_t / coro_stats.median_s),
        ]);
    }

    println!("── Fig. 3(A): runtimes ─────────────────────────────────────");
    println!("{}", fig3a.render());
    println!("── Fig. 3(B): coroutine speedup over threads ───────────────");
    println!("{}", fig3b.render());
    println!("paper claim: coroutines ≥ 2× thread throughput, roughly flat");
    println!("across buffer sizes and event counts (single-core testbed here;");
    println!("see EXPERIMENTS.md for the recorded comparison).");
}
