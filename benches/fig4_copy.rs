//! Fig. 4(B) reproduction: host→device copy cost per scenario.
//!
//! Replays a synthetic paper-rate recording through the four scenarios
//! and reports the HtoD copy time (ms and % of runtime), operation
//! count, and bytes — the paper's plot shows ~7% of runtime for the
//! dense scenarios vs <2% for the sparse ones on PCIe; on this CPU
//! substrate the *ratios* (bytes, per-frame copy time) are the
//! reproduced quantities (DESIGN.md §Hardware-Adaptation).
//!
//! Run: `cargo bench --bench fig4_copy`

use aestream::bench::Table;
use aestream::camera;
use aestream::coordinator::{run_scenario, ScenarioConfig};
use aestream::runtime::Device;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let duration_us: u64 = if fast { 300_000 } else { 2_000_000 };

    eprintln!("synthesizing {} ms recording…", duration_us / 1000);
    let recording = camera::paper_recording(duration_us, 42);
    eprintln!("{} events; opening device…", recording.len());
    let device = Device::open_default()?;

    let mut table = Table::new(&[
        "scenario",
        "HtoD ms",
        "HtoD %",
        "HtoD ops",
        "HtoD MB",
        "B/frame",
        "state ms",
        "DtoH ms",
        "wall ms",
    ]);
    let mut per_frame = Vec::new();
    for cfg in ScenarioConfig::paper_four(1.0) {
        let r = run_scenario(&device, &recording, &cfg)?;
        per_frame.push((r.label.clone(), r.stats.htod_bytes / r.frames.max(1), r.stats.htod_ns / r.frames.max(1)));
        table.row(&[
            r.label.clone(),
            format!("{:.2}", r.stats.htod_ns as f64 / 1e6),
            format!("{:.3}", r.htod_percent()),
            r.stats.htod_ops.to_string(),
            format!("{:.2}", r.stats.htod_bytes as f64 / 1e6),
            (r.stats.htod_bytes / r.frames.max(1)).to_string(),
            format!("{:.2}", r.stats.state_ns as f64 / 1e6),
            format!("{:.2}", r.stats.dtoh_ns as f64 / 1e6),
            format!("{:.0}", r.wall.as_secs_f64() * 1e3),
        ]);
    }
    println!("Fig. 4(B) — host→device copy cost (input transfers)\n");
    println!("{}", table.render());

    // Headline ratios, paper: dense ≈ 7% vs sparse <2% of runtime; ≥5×
    // fewer copy work for sparse.
    let dense_b = per_frame.iter().find(|r| r.0 == "threads+dense").unwrap();
    let sparse_b = per_frame.iter().find(|r| r.0 == "threads+sparse").unwrap();
    println!(
        "per-frame input copy: dense {} B / {} ns vs sparse {} B / {} ns",
        dense_b.1, dense_b.2, sparse_b.1, sparse_b.2
    );
    println!(
        "→ sparse moves {:.1}× fewer bytes, {:.1}× less copy time per frame (paper: ≥5× / ~3.5×)",
        dense_b.1 as f64 / sparse_b.1 as f64,
        dense_b.2 as f64 / sparse_b.2.max(1) as f64,
    );
    Ok(())
}
