//! Batch-collect vs incremental streaming drivers.
//!
//! The old `run_stream` collected the whole source into a `Vec` before
//! processing; the redesigned layer streams bounded chunks through the
//! coroutine runtime. This bench quantifies the trade on a RAM-cached
//! recording: throughput (events/s) and peak in-flight events (the
//! memory bound) for the batch baseline, the sync chunked driver, and
//! the coroutine driver at several chunk sizes.
//!
//! A fan-in section benchmarks the topology driver: the same total
//! event count split across 1, 2, or 4 sources, merged in timestamp
//! order either by the single-thread coroutine merge or with one OS
//! thread per source feeding the executor over the lock-free ring.
//!
//! A merge lane-sweep section benchmarks the k-way merge core alone at
//! 1/4/16/128 lanes on bursty streams: bulk drain (loser tree + run
//! gallop) vs the per-event linear scan kept as `pop_min_linear`, with
//! pool hit rate per row and an asserted ≥2× bulk win at 128 lanes,
//! plus a zero-clone tripwire on the single-active-lane fused path.
//!
//! A graph section runs the same fan-in shape twice — through the
//! legacy `stream::run_topology` entry and described as a `GraphSpec`
//! (built + validated + compiled per iteration) — and asserts the
//! graph-compiled path does not regress: the graph layer is a
//! description, the engine underneath is shared.
//!
//! A broadcast fan-out section measures the zero-copy chunk currency:
//! one source delivered to 2/4 sinks as refcounted chunks vs a sink
//! that forces the old deep-copy-per-delivery, reporting
//! `bytes_moved_per_event` from the process-wide copy counters and
//! asserting the zero-copy path moves strictly fewer bytes.
//!
//! A sharded-stage section benchmarks the stage graph: one stateful
//! stage chain (refractory + denoise, the heaviest per-event work in
//! the op set) run serial vs stripe-sharded over 1/2/4 shard workers,
//! inline coroutines vs one OS thread per shard.
//!
//! A serving section benchmarks the network plane: a `tcp-listen`
//! topology fed by 1/16/128 simulated loopback clients, reporting
//! end-to-end events/s, the merge's peak buffered events, and a peak
//! RSS proxy (`VmHWM` from /proc/self/status) as the memory-bound
//! check.
//!
//! A codec-plane group closes the sweep: a decode-bound EVT2/raw
//! recording replayed through `FileSource` inline vs the shared decode
//! pool (pooled must win ≥1.5× at 4 workers, asserted where the host
//! has the cores), a camera-like-trace copy ablation (zero-copy vs
//! forced deep clone vs pooled decode), and the 128-client serve again
//! on a fixed 4-thread decode budget with a live `codec:` thread
//! census asserted against the budget.
//!
//! A durable-edge section feeds a throttled sink through an unbounded
//! in-memory queue vs the disk-buffered edge (`stream::buffer`): same
//! producer and slow sink, with the memory edge's peak queued bytes
//! reported against the disk edge's asserted bounded front
//! (`peak_mem_batches ≤ front_batches`) — the memory-vs-durability
//! trade in two rows.
//!
//! Emits the human table plus one JSON object per configuration (the
//! same flat `{"name": …, "mean_s": …, …}` shape as the other benches'
//! stats), so dashboards can scrape either.
//!
//! Run: `cargo bench --bench stream_pipeline`

use aestream::aer::{Event, Resolution};
use aestream::bench::{fmt_rate, measure, Table};
use aestream::pipeline::{ops, Pipeline, PipelineSpec, StageSpec};
use aestream::stream::{
    self, run_topology, AdaptiveConfig, ControllerKind, MemorySource, NullSink, RoutePolicy,
    StageGraph, StageOptions, StreamConfig, StreamDriver, ThreadMode, TopologyConfig,
};
use aestream::testutil::{hotspot_events_seeded, synthetic_events, synthetic_events_seeded};

fn main() {
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let n: usize = if fast { 100_000 } else { 2_000_000 };
    let samples = if fast { 3 } else { 8 };
    let res = Resolution::DAVIS_346;
    let events = synthetic_events(n, res.width, res.height);

    println!("Streaming drivers over {n} events (DAVIS346 geometry)\n");
    let mut table = Table::new(&[
        "driver", "chunk", "mean ± std", "throughput", "peak in-flight", "backpressure",
    ]);
    let mut json_lines = Vec::new();

    // --- batch baseline: materialize, then process (the old run_stream).
    {
        let stats = measure(1, samples, || {
            let collected: Vec<_> = events.clone(); // the O(stream) copy
            let processed = Pipeline::new().process(&collected);
            std::hint::black_box(processed.len());
        });
        table.row(&[
            "batch-collect".into(),
            "∞".into(),
            stats.display_mean(),
            fmt_rate(stats.throughput(n as u64), "ev/s"),
            n.to_string(),
            "-".into(),
        ]);
        json_lines.push(format!(
            "{{\"name\":\"batch-collect\",\"chunk\":{n},\"mean_s\":{:.6},\
             \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
             \"peak_in_flight\":{n},\"backpressure_waits\":0}}",
            stats.mean_s,
            stats.std_s,
            stats.min_s,
            stats.throughput(n as u64),
        ));
    }

    // --- incremental drivers.
    let configs: Vec<(String, StreamConfig)> = vec![
        ("sync".into(), StreamConfig { chunk_size: 4096, driver: StreamDriver::Sync }),
        (
            "coro".into(),
            StreamConfig {
                chunk_size: 1024,
                driver: StreamDriver::Coroutine { channel_capacity: 1 },
            },
        ),
        (
            "coro".into(),
            StreamConfig {
                chunk_size: 4096,
                driver: StreamDriver::Coroutine { channel_capacity: 1 },
            },
        ),
        (
            "coro".into(),
            StreamConfig {
                chunk_size: 16384,
                driver: StreamDriver::Coroutine { channel_capacity: 1 },
            },
        ),
        (
            "coro×4".into(),
            StreamConfig {
                chunk_size: 4096,
                driver: StreamDriver::Coroutine { channel_capacity: 4 },
            },
        ),
    ];

    for (name, config) in configs {
        let mut peak = 0usize;
        let mut waits = 0u64;
        let mut bpe = 0.0f64;
        let stats = measure(1, samples, || {
            let mut source = MemorySource::new(events.clone(), res, config.chunk_size);
            let mut sink = NullSink::default();
            let before = aestream::stream::copy_counters();
            let report =
                stream::run(&mut source, &mut Pipeline::new(), &mut sink, config).unwrap();
            let delta = aestream::stream::copy_counters().delta(&before);
            assert_eq!(report.events_in, n as u64);
            peak = report.peak_in_flight;
            waits = report.backpressure_waits;
            bpe = delta.bytes_moved as f64 / n as f64;
            std::hint::black_box(report.events_out);
        });
        table.row(&[
            name.clone(),
            config.chunk_size.to_string(),
            stats.display_mean(),
            fmt_rate(stats.throughput(n as u64), "ev/s"),
            peak.to_string(),
            waits.to_string(),
        ]);
        json_lines.push(format!(
            "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
             \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
             \"events_per_sec\":{:.0},\"bytes_moved_per_event\":{bpe:.3},\
             \"peak_in_flight\":{peak},\"backpressure_waits\":{waits}}}",
            config.chunk_size,
            stats.mean_s,
            stats.std_s,
            stats.min_s,
            stats.throughput(n as u64),
            stats.throughput(n as u64),
        ));
    }

    // --- fan-in: k sources merged in timestamp order through the
    // topology driver, single-thread coroutine vs one OS thread per
    // source. Total event count is held constant so the merge overhead
    // (and the threading win/loss) is the only variable.
    for &k in &[1usize, 2, 4] {
        let per = n / k;
        let streams: Vec<Vec<Event>> = (0..k)
            .map(|i| synthetic_events_seeded(per, res.width, res.height, 0xFA0 + i as u64))
            .collect();
        for &threaded in &[false, true] {
            let name = format!("fanin{k}-{}", if threaded { "threads" } else { "coro" });
            let config = TopologyConfig {
                chunk_size: 4096,
                driver: StreamDriver::Coroutine { channel_capacity: 1 },
                threads: if threaded {
                    ThreadMode::PerSourceThread
                } else {
                    ThreadMode::Inline
                },
                route: RoutePolicy::Broadcast,
                decode_threads: None,
                adaptive: None,
            };
            let mut peak = 0usize;
            let mut waits = 0u64;
            let stats = measure(1, samples, || {
                let sources: Vec<MemorySource> = streams
                    .iter()
                    .map(|s| MemorySource::new(s.clone(), res, config.chunk_size))
                    .collect();
                let mut pipeline = Pipeline::new();
                let report = run_topology(
                    sources,
                    &mut pipeline,
                    vec![NullSink::default()],
                    None,
                    &config,
                )
                .unwrap();
                assert_eq!(report.events_in, (per * k) as u64);
                // Edge-channel peak only, so the field means the same
                // thing in every row of the JSON output; the merge's
                // carry depth is bounded separately (≤ sources × chunk,
                // asserted by the topology tests).
                peak = report.peak_in_flight;
                waits = report.backpressure_waits;
                std::hint::black_box(report.events_out);
            });
            table.row(&[
                name.clone(),
                config.chunk_size.to_string(),
                stats.display_mean(),
                fmt_rate(stats.throughput((per * k) as u64), "ev/s"),
                peak.to_string(),
                waits.to_string(),
            ]);
            json_lines.push(format!(
                "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"peak_in_flight\":{peak},\"backpressure_waits\":{waits}}}",
                config.chunk_size,
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput((per * k) as u64),
            ));
        }
    }

    // --- merge lane sweep: the k-way merge core itself, fed bursty
    // per-lane batches (64 consecutive timestamps per burst, bursts
    // round-robined over lanes), drained either in bulk (loser tree +
    // run gallop) or through the old O(k) per-event linear scan kept as
    // `pop_min_linear`. Both paths share the identical segment feed and
    // buffer pool, so the rows isolate pure selection/emission cost.
    // The 128-lane ratio is asserted: bulk must be ≥2× the scan.
    {
        use aestream::aer::Polarity;
        use aestream::stream::merge::MergeCore;
        use aestream::stream::{copy_counters, ChunkPool, FusedSource, PoolCounters};

        /// Events per burst (one contiguous run through the merge).
        const BURST: usize = 64;
        /// Events per pushed segment (the producer batch size).
        const SEG: usize = 4096;

        /// Split `n` strictly-increasing timestamps into `k` per-lane
        /// streams, `BURST` consecutive events at a time.
        fn burst_lanes(n: usize, k: usize, res: Resolution) -> Vec<Vec<Event>> {
            let mut lanes = vec![Vec::new(); k];
            for b in 0..n / BURST {
                let lane = &mut lanes[b % k];
                for j in 0..BURST {
                    let t = (b * BURST + j) as u64;
                    lane.push(Event {
                        t,
                        x: (t % res.width as u64) as u16,
                        y: ((t / res.width as u64) % res.height as u64) as u16,
                        p: Polarity::from_bool(t & 1 == 1),
                    });
                }
            }
            lanes
        }

        /// One full merge: refill every dry lane from its stream (one
        /// pooled segment per refill), drain until a lane dries, repeat.
        /// Identical feed for both modes; only the pop differs.
        fn drive(lanes_data: &[Vec<Event>], bulk: bool) -> (u64, PoolCounters) {
            let k = lanes_data.len();
            let pool = ChunkPool::new();
            let mut core: MergeCore<Event> = MergeCore::new(k);
            core.set_keep_drained(true);
            let mut pos = vec![0usize; k];
            let mut out = 0u64;
            while !core.all_done() {
                for i in 0..k {
                    if core.lane_len(i) > 0 {
                        continue;
                    }
                    if pos[i] < lanes_data[i].len() {
                        let end = (pos[i] + SEG).min(lanes_data[i].len());
                        let mut buf = pool.get(end - pos[i]);
                        buf.extend_from_slice(&lanes_data[i][pos[i]..end]);
                        pos[i] = end;
                        core.push_vec(i, buf);
                    } else if !core.is_exhausted(i) {
                        core.exhaust(i);
                    }
                }
                // Every lane is now non-empty or exhausted, so popping
                // cannot leapfrog pending data; stop when the consumed
                // lane dries (the refill point).
                if bulk {
                    while let Some(run) = core.pop_run(usize::MAX, |ev: &Event| ev.t) {
                        out += run.len() as u64;
                        let lane = run.lane();
                        std::hint::black_box(run.as_slice().as_ptr());
                        if core.lane_len(lane) == 0 {
                            break;
                        }
                    }
                } else {
                    while let Some((lane, ev)) = core.pop_min_linear(|ev: &Event| ev.t) {
                        out += 1;
                        std::hint::black_box(ev.t);
                        if core.lane_len(lane) == 0 {
                            break;
                        }
                    }
                }
                for buf in core.take_drained() {
                    pool.recycle_arc(buf);
                }
            }
            (out, pool.counters())
        }

        let mut means = std::collections::HashMap::new();
        for &k in &[1usize, 4, 16, 128] {
            let lanes = burst_lanes(n, k, res);
            let total: u64 = lanes.iter().map(|l| l.len() as u64).sum();
            for &bulk in &[true, false] {
                let name = format!("merge{k}-{}", if bulk { "bulk" } else { "linear" });
                let mut hit_rate = 0.0f64;
                let stats = measure(1, samples, || {
                    let (out, counters) = drive(&lanes, bulk);
                    assert_eq!(out, total, "{name}: merge lost events");
                    let served = counters.hits + counters.misses;
                    hit_rate = if served == 0 {
                        0.0
                    } else {
                        counters.hits as f64 / served as f64
                    };
                    std::hint::black_box(out);
                });
                means.insert((k, bulk), stats.mean_s);
                table.row(&[
                    name.clone(),
                    SEG.to_string(),
                    stats.display_mean(),
                    fmt_rate(stats.throughput(total), "ev/s"),
                    format!("pool {:.0}%", hit_rate * 100.0),
                    "-".into(),
                ]);
                json_lines.push(format!(
                    "{{\"name\":\"{name}\",\"chunk\":{SEG},\"mean_s\":{:.6},\
                     \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                     \"events_per_sec\":{:.0},\"pool_hit_rate\":{hit_rate:.3}}}",
                    stats.mean_s,
                    stats.std_s,
                    stats.min_s,
                    stats.throughput(total),
                    stats.throughput(total),
                ));
            }
        }
        assert!(
            means[&(128usize, true)] * 2.0 <= means[&(128usize, false)],
            "bulk merge must be ≥2× the linear scan at 128 lanes ({:.6}s vs {:.6}s)",
            means[&(128usize, true)],
            means[&(128usize, false)]
        );

        // Zero-copy tripwire (benches run sequentially, so the
        // process-wide counters are exact): a fused merge whose second
        // lane is exhausted has one active lane and must emit pure run
        // views — zero chunk clones end to end.
        let events = synthetic_events_seeded(n.min(200_000), res.width, res.height, 0x2E0C);
        let mut fused = FusedSource::new(
            vec![
                MemorySource::new(events.clone(), res, SEG),
                MemorySource::new(Vec::new(), res, SEG),
            ],
            None,
            SEG,
        );
        let before = copy_counters();
        let mut out = 0u64;
        while let Some(chunk) = fused.next_chunk().unwrap() {
            out += chunk.len() as u64;
            std::hint::black_box(chunk.as_slice().as_ptr());
        }
        assert_eq!(out, events.len() as u64);
        let zero_d = copy_counters().delta(&before);
        assert_eq!(zero_d.chunks_cloned, 0, "single-active-lane merge must stay zero-copy");
        json_lines.push(format!(
            "{{\"name\":\"merge1-zerocopy\",\"chunk\":{SEG},\"events\":{out},\
             \"chunks_cloned\":{},\"bytes_moved\":{}}}",
            zero_d.chunks_cloned, zero_d.bytes_moved,
        ));
    }

    // --- graph-compiled topology vs the legacy engine entry: the same
    // 2-source fan-in broadcast shape, once through stream::run_topology
    // (the fixed pre-redesign path) and once described as a GraphSpec
    // and compiled (builder + validate + compile every iteration, so
    // the rows include the full lowering cost). Event counts are
    // asserted equal, and the graph path must not regress.
    {
        use aestream::stream::{GraphConfig, SourceOptions, Topology};
        let k = 2usize;
        let per = n / k;
        let streams: Vec<Vec<Event>> = (0..k)
            .map(|i| synthetic_events_seeded(per, res.width, res.height, 0x6AF + i as u64))
            .collect();
        let config = TopologyConfig {
            chunk_size: 4096,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
            threads: ThreadMode::Inline,
            route: RoutePolicy::Broadcast,
            adaptive: None,
            decode_threads: None,
        };
        let mut means = std::collections::HashMap::new();
        for &graphed in &[false, true] {
            let name = if graphed { "graph-fanin2" } else { "legacy-fanin2" };
            let mut peak = 0usize;
            let mut waits = 0u64;
            let stats = measure(1, samples, || {
                let report = if graphed {
                    let mut builder = Topology::builder();
                    for (i, s) in streams.iter().enumerate() {
                        builder = builder.source_with(
                            &format!("in{i}"),
                            MemorySource::new(s.clone(), res, config.chunk_size),
                            SourceOptions::default(),
                        );
                    }
                    builder
                        .merge("fuse", &["in0", "in1"])
                        .sink("out", NullSink::default())
                        .build()
                        .run(GraphConfig {
                            chunk_size: config.chunk_size,
                            driver: config.driver,
                            adaptive: None,
                            report_json: None,
                            decode_threads: None,
                        })
                        .unwrap()
                } else {
                    let sources: Vec<MemorySource> = streams
                        .iter()
                        .map(|s| MemorySource::new(s.clone(), res, config.chunk_size))
                        .collect();
                    run_topology(
                        sources,
                        &mut Pipeline::new(),
                        vec![NullSink::default()],
                        None,
                        &config,
                    )
                    .unwrap()
                };
                assert_eq!(report.events_in, (per * k) as u64, "{name}");
                peak = report.peak_in_flight;
                waits = report.backpressure_waits;
                std::hint::black_box(report.events_out);
            });
            means.insert(name, stats.mean_s);
            table.row(&[
                name.into(),
                config.chunk_size.to_string(),
                stats.display_mean(),
                fmt_rate(stats.throughput((per * k) as u64), "ev/s"),
                peak.to_string(),
                waits.to_string(),
            ]);
            json_lines.push(format!(
                "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"peak_in_flight\":{peak},\"backpressure_waits\":{waits}}}",
                config.chunk_size,
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput((per * k) as u64),
            ));
        }
        // The graph layer is a description, not a new engine: compile
        // overhead is per-run, not per-event, so anything past noise is
        // a regression. 1.5× bounds CI jitter on shared runners.
        assert!(
            means["graph-fanin2"] <= means["legacy-fanin2"] * 1.5,
            "graph-compiled path regressed vs legacy ({:.6}s vs {:.6}s)",
            means["graph-fanin2"],
            means["legacy-fanin2"]
        );
    }

    // --- broadcast fan-out: one source delivered to m sinks, the
    // zero-copy chunk routing vs a sink wrapper that forces the
    // pre-refactor behaviour (deep copy per delivery). The process-wide
    // copy counters are exact here (benches run sequentially), so the
    // tentpole property — broadcast is a refcount bump, not a copy — is
    // asserted where it is measured: at 2+ sinks the zero-copy path must
    // move strictly fewer bytes per event than the cloning baseline.
    {
        use aestream::stream::{copy_counters, EventChunk, EventSink, SinkSummary};

        /// Forces the pre-refactor delivery: every chunk is deep-copied
        /// into an owned `Vec` (counted) before the sink reads it.
        struct CloningSink(NullSink);
        impl EventSink for CloningSink {
            fn consume(&mut self, batch: &[Event]) -> anyhow::Result<()> {
                self.0.consume(batch)
            }
            fn consume_chunk(&mut self, chunk: &EventChunk) -> anyhow::Result<()> {
                let owned = chunk.to_vec(); // the counted deep copy
                self.0.consume(&owned)
            }
            fn finish(&mut self) -> anyhow::Result<SinkSummary> {
                self.0.finish()
            }
            fn describe(&self) -> String {
                "cloning-null".into()
            }
        }

        for &m in &[2usize, 4] {
            let mut bpe_of = std::collections::HashMap::new();
            for &cloning in &[false, true] {
                let name = format!("bcast{m}-{}", if cloning { "clone" } else { "zerocopy" });
                let config = TopologyConfig {
                    chunk_size: 4096,
                    driver: StreamDriver::Coroutine { channel_capacity: 1 },
                    threads: ThreadMode::Inline,
                    route: RoutePolicy::Broadcast,
                    adaptive: None,
                    decode_threads: None,
                };
                let mut bpe = 0.0f64;
                let mut cloned = 0u64;
                let mut waits = 0u64;
                let stats = measure(1, samples, || {
                    let mut source = MemorySource::new(events.clone(), res, config.chunk_size);
                    let mut pipeline = Pipeline::new();
                    let before = copy_counters();
                    let report = if cloning {
                        let sinks: Vec<CloningSink> =
                            (0..m).map(|_| CloningSink(NullSink::default())).collect();
                        run_topology(vec![&mut source], &mut pipeline, sinks, None, &config)
                            .unwrap()
                    } else {
                        let sinks: Vec<NullSink> = (0..m).map(|_| NullSink::default()).collect();
                        run_topology(vec![&mut source], &mut pipeline, sinks, None, &config)
                            .unwrap()
                    };
                    let delta = copy_counters().delta(&before);
                    assert_eq!(report.events_in, n as u64);
                    bpe = delta.bytes_moved as f64 / n as f64;
                    cloned = delta.chunks_cloned;
                    waits = report.backpressure_waits;
                    std::hint::black_box(report.events_out);
                });
                bpe_of.insert(cloning, bpe);
                table.row(&[
                    name.clone(),
                    config.chunk_size.to_string(),
                    stats.display_mean(),
                    fmt_rate(stats.throughput(n as u64), "ev/s"),
                    format!("{bpe:.1} B/ev"),
                    waits.to_string(),
                ]);
                json_lines.push(format!(
                    "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
                     \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                     \"events_per_sec\":{:.0},\"bytes_moved_per_event\":{bpe:.3},\
                     \"chunks_cloned\":{cloned},\"backpressure_waits\":{waits}}}",
                    config.chunk_size,
                    stats.mean_s,
                    stats.std_s,
                    stats.min_s,
                    stats.throughput(n as u64),
                    stats.throughput(n as u64),
                ));
            }
            assert!(
                bpe_of[&false] < bpe_of[&true],
                "zero-copy broadcast must move strictly fewer bytes/event than \
                 the cloning baseline at {m} sinks ({} vs {})",
                bpe_of[&false],
                bpe_of[&true]
            );
        }
    }

    // --- sharded stages: a stateful filter chain run serial vs as
    // stripe-sharded stage nodes (inline workers vs one OS thread per
    // shard). Identical output is asserted against the serial run, so
    // these rows track pure execution-strategy cost/speedup.
    {
        let stage_spec = || {
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 100)))
                .then(StageSpec::new(|res: Resolution| {
                    ops::BackgroundActivityFilter::new(res, 1000)
                }))
        };
        let serial_out = stage_spec().build_pipeline(res).process(&events).len() as u64;
        for &shards in &[1usize, 2, 4] {
            for &threaded in &[false, true] {
                if shards == 1 && threaded {
                    continue; // one worker thread is never interesting
                }
                let name = format!(
                    "shard{shards}-{}",
                    if threaded { "threads" } else { "coro" }
                );
                let config = TopologyConfig {
                    chunk_size: 4096,
                    driver: StreamDriver::Coroutine { channel_capacity: 1 },
                    threads: ThreadMode::Inline,
                    route: RoutePolicy::Broadcast,
                    adaptive: None,
                    decode_threads: None,
                };
                let spec = stage_spec();
                let mut peak = 0usize;
                let mut waits = 0u64;
                let stats = measure(1, samples, || {
                    let mut graph = StageGraph::compile(
                        &spec,
                        res,
                        &StageOptions { shards, shard_threads: threaded },
                    );
                    let mut source = MemorySource::new(events.clone(), res, config.chunk_size);
                    let report = run_topology(
                        vec![&mut source],
                        &mut graph,
                        vec![NullSink::default()],
                        None,
                        &config,
                    )
                    .unwrap();
                    assert_eq!(report.events_in, n as u64);
                    assert_eq!(report.events_out, serial_out, "sharded ≠ serial");
                    peak = report.peak_in_flight;
                    waits = report.backpressure_waits;
                    std::hint::black_box(report.events_out);
                });
                table.row(&[
                    name.clone(),
                    config.chunk_size.to_string(),
                    stats.display_mean(),
                    fmt_rate(stats.throughput(n as u64), "ev/s"),
                    peak.to_string(),
                    waits.to_string(),
                ]);
                json_lines.push(format!(
                    "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
                     \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                     \"peak_in_flight\":{peak},\"backpressure_waits\":{waits}}}",
                    config.chunk_size,
                    stats.mean_s,
                    stats.std_s,
                    stats.min_s,
                    stats.throughput(n as u64),
                ));
            }
        }
    }

    // --- adaptive runtime: a synthetic hotspot stream (90% of events
    // in the left eighth of the canvas) through a 4-shard stateful
    // stage, static uniform cut vs the skew controller vs skew+chunk.
    // Output equality with serial is asserted per run, and the final
    // shard skew is reported (and asserted lower for `skew`) — the
    // bench doubles as the perf regression gate for the controllers.
    {
        let hot_n = if fast { 200_000 } else { 2_000_000 };
        let hotspot: Vec<Event> = hotspot_events_seeded(hot_n, res.width, res.height, 0xADA);
        let stage_spec = || {
            PipelineSpec::new()
                .then(StageSpec::new(|res: Resolution| ops::RefractoryFilter::new(res, 3)))
        };
        let serial_out = stage_spec().build_pipeline(res).process(&hotspot).len() as u64;
        let variants: [(&str, Option<AdaptiveConfig>); 3] = [
            ("adaptive-static", None),
            (
                "adaptive-skew",
                Some(AdaptiveConfig::new(vec![ControllerKind::Skew]).with_epoch(32)),
            ),
            (
                "adaptive-skew+chunk",
                Some(
                    AdaptiveConfig::new(vec![
                        ControllerKind::Skew,
                        ControllerKind::Chunk,
                    ])
                    .with_epoch(32),
                ),
            ),
        ];
        let mut skews = std::collections::HashMap::new();
        for (name, adaptive) in variants {
            let config = TopologyConfig {
                chunk_size: 4096,
                driver: StreamDriver::Coroutine { channel_capacity: 1 },
                threads: ThreadMode::Inline,
                route: RoutePolicy::Broadcast,
                adaptive,
                decode_threads: None,
            };
            let spec = stage_spec();
            let mut skew = 0.0f64;
            let mut recuts = 0usize;
            let mut final_chunk = config.chunk_size;
            let mut waits = 0u64;
            let stats = measure(1, samples, || {
                let mut graph = StageGraph::compile(
                    &spec,
                    res,
                    &StageOptions { shards: 4, shard_threads: false },
                );
                let mut source = MemorySource::new(hotspot.clone(), res, config.chunk_size);
                let report = run_topology(
                    vec![&mut source],
                    &mut graph,
                    vec![NullSink::default()],
                    None,
                    &config,
                )
                .unwrap();
                assert_eq!(report.events_out, serial_out, "adaptive ≠ serial");
                skew = report.stages[0].shard_skew();
                waits = report.backpressure_waits;
                if let Some(adaptive) = &report.adaptive {
                    recuts = adaptive.recuts.len();
                    final_chunk = adaptive.final_chunk;
                }
                std::hint::black_box(report.events_out);
            });
            skews.insert(name, skew);
            table.row(&[
                name.into(),
                final_chunk.to_string(),
                stats.display_mean(),
                fmt_rate(stats.throughput(hot_n as u64), "ev/s"),
                format!("skew {skew:.2}"),
                waits.to_string(),
            ]);
            json_lines.push(format!(
                "{{\"name\":\"{name}\",\"chunk\":{final_chunk},\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"final_shard_skew\":{skew:.4},\"recuts\":{recuts},\
                 \"backpressure_waits\":{waits}}}",
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput(hot_n as u64),
            ));
        }
        assert!(
            skews["adaptive-skew"] < skews["adaptive-static"],
            "skew controller must reduce final shard skew ({} vs {})",
            skews["adaptive-skew"],
            skews["adaptive-static"]
        );
    }

    // --- serving plane: a tcp-listen topology fed over loopback by
    // 1/16/128 concurrent clients, each pushing its share of the stream
    // as raw SPIF words. Rows report end-to-end throughput (connect →
    // last event through the sink), the merge's peak buffered events,
    // and VmHWM as a peak-RSS proxy — the `clients × window` memory
    // bound made observable.
    {
        use aestream::net::spif;
        use aestream::serve::{ListenerConfig, ListenerSource};
        use aestream::stream::{GraphConfig, Topology};
        use std::io::Write;
        use std::net::TcpStream;

        let serve_n: usize = if fast { 96_000 } else { 1_920_000 };
        let serve_samples = if fast { 2 } else { 4 };
        for &k in &[1usize, 16, 128] {
            let per = serve_n / k;
            let name = format!("serve{k}");
            // Per-client wire payloads, encoded once outside the timer.
            let payloads: Vec<Vec<u8>> = (0..k)
                .map(|i| {
                    let events =
                        synthetic_events_seeded(per, res.width, res.height, 0x5E47 + i as u64);
                    let mut bytes = Vec::with_capacity(events.len() * 4);
                    for ev in &events {
                        bytes.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
                    }
                    bytes
                })
                .collect();
            let mut peak = 0usize;
            let mut waits = 0u64;
            let stats = measure(1, serve_samples, || {
                let listener = ListenerSource::bind_tcp(
                    "127.0.0.1:0",
                    ListenerConfig::new(res)
                        .max_clients(k.max(2))
                        .idle_timeout(std::time::Duration::from_secs(10)),
                )
                .unwrap();
                let addr = listener.local_addr();
                let hub = listener.hub();
                let senders: Vec<_> = payloads
                    .iter()
                    .map(|payload| {
                        let payload = payload.clone();
                        std::thread::spawn(move || {
                            let mut conn = TcpStream::connect(addr).unwrap();
                            for chunk in payload.chunks(16 * 1024) {
                                conn.write_all(chunk).unwrap();
                            }
                        })
                    })
                    .collect();
                // Close the plane once every client connected and left;
                // queued batches still drain before the merge ends.
                let supervisor = {
                    let hub = hub.clone();
                    let k = k as u64;
                    std::thread::spawn(move || {
                        while hub.admitted() < k || hub.active_clients() > 0 {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        hub.shutdown();
                    })
                };
                let report = Topology::builder()
                    .listen("net", listener)
                    .sink("out", NullSink::default())
                    .build()
                    .run(GraphConfig { chunk_size: 4096, ..Default::default() })
                    .unwrap();
                for sender in senders {
                    sender.join().unwrap();
                }
                supervisor.join().unwrap();
                assert_eq!(report.events_in, (per * k) as u64, "{name}: lost events");
                peak = report.merge_peak_buffered;
                waits = report.backpressure_waits;
                std::hint::black_box(report.events_out);
            });
            let rss_kb = peak_rss_kb();
            table.row(&[
                name.clone(),
                "4096".into(),
                stats.display_mean(),
                fmt_rate(stats.throughput((per * k) as u64), "ev/s"),
                peak.to_string(),
                waits.to_string(),
            ]);
            json_lines.push(format!(
                "{{\"name\":\"{name}\",\"chunk\":4096,\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"peak_in_flight\":{peak},\"backpressure_waits\":{waits},\
                 \"peak_rss_kb\":{rss_kb}}}",
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput((per * k) as u64),
            ));
        }
    }

    // --- codec plane, file replay: a decode-bound recording (EVT2 and
    // raw) replayed through FileSource, inline decode vs the shared
    // worker pool at 4 workers. The event count is held fixed — decode
    // cost only dominates at scale, and the asserted ratio would be
    // meaningless on a toy file. The pool must win ≥1.5× (asserted when
    // the host actually has the cores to run 4 workers).
    {
        use aestream::formats::Format;
        use aestream::stream::{
            CodecPlane, CodecPlaneConfig, EventSink, EventSource, FileSink, FileSource,
        };

        const DECODE_WORKERS: usize = 4;
        let decode_n = 1_500_000usize;
        let decode_samples = if fast { 2 } else { 5 };
        let trace = synthetic_events_seeded(decode_n, res.width, res.height, 0xDECD);
        let dir = std::env::temp_dir()
            .join(format!("aestream-bench-decode-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        for format in [Format::Evt2, Format::Raw] {
            let path = dir.join(format!("replay.{}", format.codec().name()));
            let mut sink = FileSink::create(&path, format, res).unwrap();
            sink.consume(&trace).unwrap();
            sink.finish().unwrap();
            let mut means = std::collections::HashMap::new();
            for &workers in &[0usize, DECODE_WORKERS] {
                let name = if workers == 0 {
                    format!("replay-{format}-inline")
                } else {
                    format!("replay-{format}-pool{workers}")
                };
                let stats = measure(1, decode_samples, || {
                    let plane = (workers > 0)
                        .then(|| CodecPlane::new(CodecPlaneConfig::with_workers(workers)));
                    let mut source = FileSource::open(&path, 16384).unwrap();
                    if let Some(plane) = &plane {
                        source.set_codec_plane(plane.clone());
                    }
                    let mut out = 0u64;
                    while let Some(batch) = source.next_batch().unwrap() {
                        out += batch.len() as u64;
                        std::hint::black_box(batch.len());
                    }
                    assert_eq!(out, decode_n as u64, "{name}: replay lost events");
                });
                means.insert(workers, stats.mean_s);
                table.row(&[
                    name.clone(),
                    "16384".into(),
                    stats.display_mean(),
                    fmt_rate(stats.throughput(decode_n as u64), "ev/s"),
                    if workers == 0 { "inline".into() } else { format!("{workers} workers") },
                    "-".into(),
                ]);
                json_lines.push(format!(
                    "{{\"name\":\"{name}\",\"chunk\":16384,\"mean_s\":{:.6},\
                     \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                     \"events_per_sec\":{:.0},\"decode_workers\":{workers}}}",
                    stats.mean_s,
                    stats.std_s,
                    stats.min_s,
                    stats.throughput(decode_n as u64),
                    stats.throughput(decode_n as u64),
                ));
            }
            if cores >= DECODE_WORKERS {
                assert!(
                    means[&DECODE_WORKERS] * 1.5 <= means[&0],
                    "pooled decode must be ≥1.5× inline for {format} replay \
                     ({:.6}s vs {:.6}s)",
                    means[&DECODE_WORKERS],
                    means[&0]
                );
            } else {
                println!(
                    "note: {cores} cores < {DECODE_WORKERS} workers — \
                     skipping the {format} replay speedup assert"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // --- real-trace copy ablation: a camera-like trace (bursty object
    // hotspots drifting under a pan, over sensor noise) through three
    // delivery strategies — the zero-copy chunk currency, a sink that
    // forces the pre-refactor deep copy per delivery, and file replay
    // decoded on the shared pool. Same flat JSON schema as every other
    // row, so the ablation is scrapeable.
    {
        use aestream::formats::Format;
        use aestream::stream::{
            copy_counters, CodecPlane, CodecPlaneConfig, EventChunk, EventSink, EventSource,
            FileSink, FileSource, SinkSummary,
        };
        use aestream::testutil::camera_trace_events_seeded;

        struct CloningSink(NullSink);
        impl EventSink for CloningSink {
            fn consume(&mut self, batch: &[Event]) -> anyhow::Result<()> {
                self.0.consume(batch)
            }
            fn consume_chunk(&mut self, chunk: &EventChunk) -> anyhow::Result<()> {
                let owned = chunk.to_vec(); // the counted deep copy
                self.0.consume(&owned)
            }
            fn finish(&mut self) -> anyhow::Result<SinkSummary> {
                self.0.finish()
            }
            fn describe(&self) -> String {
                "cloning-null".into()
            }
        }

        let cam_n = if fast { 200_000 } else { 2_000_000 };
        let cam = camera_trace_events_seeded(cam_n, res.width, res.height, 0xCA3);
        let dir = std::env::temp_dir()
            .join(format!("aestream-bench-ablate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("camera.raw");
        let mut sink = FileSink::create(&path, Format::Raw, res).unwrap();
        sink.consume(&cam).unwrap();
        sink.finish().unwrap();

        let config = TopologyConfig {
            chunk_size: 4096,
            driver: StreamDriver::Coroutine { channel_capacity: 1 },
            threads: ThreadMode::Inline,
            route: RoutePolicy::Broadcast,
            adaptive: None,
            decode_threads: None,
        };
        for variant in ["zerocopy", "clone", "pooled-decode"] {
            let name = format!("ablate-{variant}");
            let mut bpe = 0.0f64;
            let stats = measure(1, samples, || {
                let before = copy_counters();
                let out = match variant {
                    "zerocopy" => {
                        let mut source = MemorySource::new(cam.clone(), res, config.chunk_size);
                        let report = run_topology(
                            vec![&mut source],
                            &mut Pipeline::new(),
                            vec![NullSink::default()],
                            None,
                            &config,
                        )
                        .unwrap();
                        report.events_in
                    }
                    "clone" => {
                        let mut source = MemorySource::new(cam.clone(), res, config.chunk_size);
                        let report = run_topology(
                            vec![&mut source],
                            &mut Pipeline::new(),
                            vec![CloningSink(NullSink::default())],
                            None,
                            &config,
                        )
                        .unwrap();
                        report.events_in
                    }
                    _ => {
                        let plane = CodecPlane::new(CodecPlaneConfig::with_workers(4));
                        let mut source = FileSource::open(&path, config.chunk_size).unwrap();
                        source.set_codec_plane(plane.clone());
                        let mut out = 0u64;
                        while let Some(batch) = source.next_batch().unwrap() {
                            out += batch.len() as u64;
                            std::hint::black_box(batch.len());
                        }
                        out
                    }
                };
                let delta = copy_counters().delta(&before);
                assert_eq!(out, cam_n as u64, "{name}: lost events");
                bpe = delta.bytes_moved as f64 / cam_n as f64;
            });
            table.row(&[
                name.clone(),
                config.chunk_size.to_string(),
                stats.display_mean(),
                fmt_rate(stats.throughput(cam_n as u64), "ev/s"),
                format!("{bpe:.1} B/ev"),
                "-".into(),
            ]);
            json_lines.push(format!(
                "{{\"name\":\"{name}\",\"chunk\":{},\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"events_per_sec\":{:.0},\"bytes_moved_per_event\":{bpe:.3}}}",
                config.chunk_size,
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput(cam_n as u64),
                stats.throughput(cam_n as u64),
            ));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    // --- serving plane on the shared decode pool: the 128-client shape
    // again, but with `decode_threads` set — decode comes off the 128
    // reader threads onto a 4-worker budget. A live census of threads
    // named `codec:` asserts the budget held, and zero loss is asserted
    // per iteration.
    {
        use aestream::net::spif;
        use aestream::serve::{ListenerConfig, ListenerSource};
        use aestream::stream::{GraphConfig, Topology};
        use std::io::Write;
        use std::net::TcpStream;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        const WORKERS: usize = 4;
        let k = 128usize;
        let serve_n: usize = if fast { 96_000 } else { 1_920_000 };
        let serve_samples = if fast { 2 } else { 4 };
        let per = serve_n / k;
        let payloads: Vec<Vec<u8>> = (0..k)
            .map(|i| {
                let events =
                    synthetic_events_seeded(per, res.width, res.height, 0x9E47 + i as u64);
                let mut bytes = Vec::with_capacity(events.len() * 4);
                for ev in &events {
                    bytes.extend_from_slice(&spif::pack_word(ev).to_le_bytes());
                }
                bytes
            })
            .collect();
        let census_peak = Arc::new(AtomicUsize::new(0));
        let mut peak = 0usize;
        let mut waits = 0u64;
        let stats = measure(1, serve_samples, || {
            let listener = ListenerSource::bind_tcp(
                "127.0.0.1:0",
                ListenerConfig::new(res)
                    .max_clients(k + 8)
                    .idle_timeout(std::time::Duration::from_secs(10)),
            )
            .unwrap();
            let addr = listener.local_addr();
            let hub = listener.hub();
            // Senders wait for the plane so every reader takes the
            // pooled path (clients admitted earlier decode inline).
            let senders: Vec<_> = payloads
                .iter()
                .map(|payload| {
                    let payload = payload.clone();
                    let hub = hub.clone();
                    std::thread::spawn(move || {
                        while hub.decode_plane().is_none() && !hub.is_closed() {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        let mut conn = TcpStream::connect(addr).unwrap();
                        for chunk in payload.chunks(16 * 1024) {
                            conn.write_all(chunk).unwrap();
                        }
                    })
                })
                .collect();
            let supervisor = {
                let hub = hub.clone();
                let census_peak = census_peak.clone();
                let k = k as u64;
                std::thread::spawn(move || {
                    while hub.admitted() < k || hub.active_clients() > 0 {
                        census_peak.fetch_max(codec_thread_count(), Ordering::Relaxed);
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    hub.shutdown();
                })
            };
            let report = Topology::builder()
                .listen("net", listener)
                .sink("out", NullSink::default())
                .build()
                .run(GraphConfig {
                    chunk_size: 4096,
                    decode_threads: Some(WORKERS),
                    ..Default::default()
                })
                .unwrap();
            for sender in senders {
                sender.join().unwrap();
            }
            supervisor.join().unwrap();
            assert_eq!(report.events_in, (per * k) as u64, "serve128-pooled: lost events");
            assert_eq!(report.merge_dropped, 0, "serve128-pooled: merge dropped events");
            assert_eq!(report.decode_workers, WORKERS as u64);
            peak = report.merge_peak_buffered;
            waits = report.backpressure_waits;
            std::hint::black_box(report.events_out);
        });
        let census = census_peak.load(Ordering::Relaxed);
        if cfg!(target_os = "linux") {
            assert!(census >= 1, "serve128-pooled: decode threads never observed");
            assert!(
                census <= WORKERS,
                "serve128-pooled: {census} codec threads observed, budget {WORKERS}"
            );
        }
        let rss_kb = peak_rss_kb();
        table.row(&[
            "serve128-pooled".into(),
            "4096".into(),
            stats.display_mean(),
            fmt_rate(stats.throughput((per * k) as u64), "ev/s"),
            format!("{census} codec thr"),
            waits.to_string(),
        ]);
        json_lines.push(format!(
            "{{\"name\":\"serve128-pooled\",\"chunk\":4096,\"mean_s\":{:.6},\
             \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
             \"peak_in_flight\":{peak},\"backpressure_waits\":{waits},\
             \"decode_workers\":{WORKERS},\"decode_threads_peak\":{census},\
             \"peak_rss_kb\":{rss_kb}}}",
            stats.mean_s,
            stats.std_s,
            stats.min_s,
            stats.throughput((per * k) as u64),
        ));
    }

    // --- durable edge: a throttled sink behind an unbounded in-memory
    // queue vs the disk-buffered edge. Same producer, same slow sink;
    // the memory edge's backlog grows with the stream while the disk
    // edge holds its bounded front and spills the rest to the journal.
    {
        use aestream::stream::{DiskBufferConfig, DiskBufferedSink, EventSink, SinkSummary};
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;

        const CHUNK: usize = 512;
        const FRONT: usize = 4;
        let bn: usize = if fast { 50_000 } else { 400_000 };
        let delay = std::time::Duration::from_micros(150);
        let bev = synthetic_events_seeded(bn, res.width, res.height, 0xB0FF);
        let batches = bev.len().div_ceil(CHUNK) as u64;

        /// The slow far end: counts deliveries, sleeps per batch.
        struct ThrottledNull {
            delay: std::time::Duration,
            delivered: Arc<AtomicU64>,
        }
        impl EventSink for ThrottledNull {
            fn consume(&mut self, batch: &[Event]) -> anyhow::Result<()> {
                std::thread::sleep(self.delay);
                self.delivered.fetch_add(batch.len() as u64, Ordering::Relaxed);
                Ok(())
            }
            fn finish(&mut self) -> anyhow::Result<SinkSummary> {
                Ok(SinkSummary::default())
            }
        }

        /// The memory edge: an unbounded queue feeding a pump thread,
        /// tracking the peak bytes it ever held — what a slow sink
        /// costs when the edge cannot spill.
        struct QueueingSink {
            tx: Option<std::sync::mpsc::Sender<Vec<Event>>>,
            pump: Option<std::thread::JoinHandle<()>>,
            queued: Arc<AtomicU64>,
            peak: Arc<AtomicU64>,
        }
        impl QueueingSink {
            fn spawn(mut inner: ThrottledNull) -> QueueingSink {
                let (tx, rx) = std::sync::mpsc::channel::<Vec<Event>>();
                let queued = Arc::new(AtomicU64::new(0));
                let peak = Arc::new(AtomicU64::new(0));
                let q = queued.clone();
                let pump = std::thread::spawn(move || {
                    while let Ok(batch) = rx.recv() {
                        inner.consume(&batch).unwrap();
                        q.fetch_sub((batch.len() * 16) as u64, Ordering::Relaxed);
                    }
                });
                QueueingSink { tx: Some(tx), pump: Some(pump), queued, peak }
            }
        }
        impl EventSink for QueueingSink {
            fn consume(&mut self, batch: &[Event]) -> anyhow::Result<()> {
                let now = self
                    .queued
                    .fetch_add((batch.len() * 16) as u64, Ordering::Relaxed)
                    + (batch.len() * 16) as u64;
                self.peak.fetch_max(now, Ordering::Relaxed);
                self.tx.as_ref().unwrap().send(batch.to_vec()).unwrap();
                Ok(())
            }
            fn finish(&mut self) -> anyhow::Result<SinkSummary> {
                drop(self.tx.take());
                if let Some(pump) = self.pump.take() {
                    pump.join().unwrap();
                }
                Ok(SinkSummary::default())
            }
        }

        let front_bytes = (FRONT * CHUNK * 16) as u64;

        // Memory edge: backlog is unbounded.
        let delivered = Arc::new(AtomicU64::new(0));
        let mut peak_queued = 0u64;
        let stats = measure(1, samples.min(3), || {
            delivered.store(0, Ordering::Relaxed);
            let mut sink = QueueingSink::spawn(ThrottledNull {
                delay,
                delivered: delivered.clone(),
            });
            for batch in bev.chunks(CHUNK) {
                sink.consume(batch).unwrap();
            }
            sink.finish().unwrap();
            assert_eq!(delivered.load(Ordering::Relaxed), bn as u64, "bufedge-mem lost events");
            peak_queued = peak_queued.max(sink.peak.load(Ordering::Relaxed));
        });
        assert!(
            peak_queued > 4 * front_bytes,
            "bufedge-mem: expected the unbounded queue to grow well past the \
             disk edge's front ({peak_queued} B vs front {front_bytes} B)"
        );
        let rss_kb = peak_rss_kb();
        table.row(&[
            "bufedge-mem".into(),
            CHUNK.to_string(),
            stats.display_mean(),
            fmt_rate(stats.throughput(bn as u64), "ev/s"),
            format!("{} KiB queued", peak_queued / 1024),
            "0".into(),
        ]);
        json_lines.push(format!(
            "{{\"name\":\"bufedge-mem\",\"chunk\":{CHUNK},\"mean_s\":{:.6},\
             \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
             \"peak_in_flight\":{},\"backpressure_waits\":0,\
             \"peak_queued_bytes\":{peak_queued},\"peak_rss_kb\":{rss_kb}}}",
            stats.mean_s,
            stats.std_s,
            stats.min_s,
            stats.throughput(bn as u64),
            peak_queued / 16,
        ));

        // Disk edge: bounded front + journal spill.
        let dir = std::env::temp_dir()
            .join(format!("aestream-bench-bufedge-{}", std::process::id()));
        let delivered = Arc::new(AtomicU64::new(0));
        let mut peak_front = 0u64;
        let mut spilled = 0u64;
        let stats = measure(1, samples.min(3), || {
            std::fs::remove_dir_all(&dir).ok();
            delivered.store(0, Ordering::Relaxed);
            let mut config = DiskBufferConfig::new(dir.clone(), 1 << 30);
            config.front_batches = FRONT;
            config.fsync_per_batch = false;
            let mut sink = DiskBufferedSink::spawn(
                Box::new(ThrottledNull { delay, delivered: delivered.clone() }),
                config,
                "bench",
            )
            .unwrap();
            for batch in bev.chunks(CHUNK) {
                sink.consume(batch).unwrap();
            }
            sink.finish().unwrap();
            assert_eq!(delivered.load(Ordering::Relaxed), bn as u64, "bufedge-disk lost events");
            let snap = sink.stats();
            assert!(
                snap.peak_mem_batches <= FRONT as u64,
                "bufedge-disk: front exceeded its bound ({} > {FRONT})",
                snap.peak_mem_batches
            );
            assert!(snap.records_spilled > 0, "bufedge-disk: throttled sink never spilled");
            peak_front = peak_front.max(snap.peak_mem_batches);
            spilled = spilled.max(snap.records_spilled);
        });
        std::fs::remove_dir_all(&dir).ok();
        let rss_kb = peak_rss_kb();
        table.row(&[
            "bufedge-disk".into(),
            CHUNK.to_string(),
            stats.display_mean(),
            fmt_rate(stats.throughput(bn as u64), "ev/s"),
            format!("{peak_front}/{FRONT} front batches"),
            "0".into(),
        ]);
        json_lines.push(format!(
            "{{\"name\":\"bufedge-disk\",\"chunk\":{CHUNK},\"mean_s\":{:.6},\
             \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
             \"peak_in_flight\":{},\"backpressure_waits\":0,\
             \"front_batches\":{FRONT},\"peak_mem_batches\":{peak_front},\
             \"records_spilled\":{spilled},\"batches\":{batches},\
             \"peak_rss_kb\":{rss_kb}}}",
            stats.mean_s,
            stats.std_s,
            stats.min_s,
            stats.throughput(bn as u64),
            peak_front as usize * CHUNK,
        ));
    }

    println!("{}", table.render());
    println!("peak in-flight is the memory bound: batch-collect holds the whole");
    println!("stream; the incremental drivers hold ≤ capacity × chunk events;");
    println!("fan-in runs additionally hold ≤ sources × chunk in merge carries;");
    println!("merge* rows drive the k-way merge core directly (bulk runs vs the");
    println!("linear scan); their 5th column is the buffer-pool hit rate.");
    println!("shard runs additionally hold ≤ one batch in flight per shard.");
    println!("adaptive-* rows stream a hotspot (90% of events in one eighth of");
    println!("the canvas); their 5th column is the final shard skew under the");
    println!("run's last stripe cut (1.0 = perfectly balanced).");
    println!("serve* rows push the stream over loopback TCP from 1/16/128");
    println!("concurrent clients; their 5th column is the merge's peak buffered");
    println!("events and the JSON adds peak_rss_kb (VmHWM) as the memory check.");
    println!("replay-* rows replay a decode-bound recording through FileSource,");
    println!("inline vs the shared codec pool (pooled must win ≥1.5× at 4");
    println!("workers); ablate-* rows run a camera-like trace through zero-copy,");
    println!("forced-clone, and pooled-decode delivery; serve128-pooled repeats");
    println!("the 128-client serve on a 4-thread decode budget, with the live");
    println!("codec-thread census asserted ≤ the budget. bufedge-* rows feed a");
    println!("throttled sink through an unbounded memory queue vs the durable");
    println!("disk-buffered edge: the memory edge's peak queued bytes grow with");
    println!("the backlog while the disk edge is asserted to hold its bounded");
    println!("front (peak_mem_batches ≤ front_batches) and spill the rest.\n");
    for line in &json_lines {
        println!("{line}");
    }
}

/// Threads of this process currently named `codec:<i>` — 0 where
/// /proc is unavailable (non-Linux).
fn codec_thread_count() -> usize {
    let Ok(entries) = std::fs::read_dir("/proc/self/task") else { return 0 };
    entries
        .flatten()
        .filter(|entry| {
            std::fs::read_to_string(entry.path().join("comm"))
                .map(|comm| comm.trim_end().starts_with("codec:"))
                .unwrap_or(false)
        })
        .count()
}

/// Peak resident set (`VmHWM`, kB) from /proc/self/status — 0 where
/// unavailable (non-Linux), keeping the JSON schema stable.
fn peak_rss_kb() -> u64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|status| {
            status.lines().find_map(|line| {
                let rest = line.strip_prefix("VmHWM:")?;
                rest.trim().trim_end_matches("kB").trim().parse().ok()
            })
        })
        .unwrap_or(0)
}
