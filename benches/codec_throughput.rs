//! Codec throughput ablation: encode/decode Mev/s for every format.
//!
//! Not a paper figure (the paper leaves cross-library format benchmarks
//! to future work, §6 Limitations) — this quantifies the cost of each
//! wire format on the ingest path, which bounds the whole pipeline when
//! reading from disk. The packed `raw` format is the one the Fig. 3
//! benchmark caches in RAM.
//!
//! Besides the human table, emits one JSON object per (format, op) in
//! the same line-oriented schema as `stream_pipeline` — `events_per_sec`
//! plus `bytes_moved_per_event` (wire bytes read or written per event,
//! which is what the decode loop physically moves) — so the two benches'
//! outputs concatenate into one scrapeable artifact. Built with
//! `--features simd`, the decode rows exercise the SSE2 word kernels in
//! `formats::simd`; the default build measures the scalar loops.
//!
//! Run: `cargo bench --bench codec_throughput`

use aestream::aer::Resolution;
use aestream::bench::{fmt_rate, measure, Table};
use aestream::formats::{EventCodec, Format};
use aestream::testutil::synthetic_events;

fn main() {
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let n: usize = if fast { 50_000 } else { 1_000_000 };
    let samples = if fast { 3 } else { 8 };
    let res = Resolution::DAVIS_346;
    let events = synthetic_events(n, res.width, res.height);

    println!("Codec throughput over {n} events (DAVIS346 geometry)\n");
    let mut table = Table::new(&[
        "format", "encode", "decode", "bytes/event", "encode ev/s", "decode ev/s",
    ]);
    let mut json_lines = Vec::new();
    for format in Format::ALL {
        let codec = format.codec();
        let mut encoded = Vec::new();
        codec.encode(&events, res, &mut encoded).unwrap();
        let wire_bpe = encoded.len() as f64 / n as f64;

        let enc = measure(1, samples, || {
            let mut buf = Vec::with_capacity(encoded.len());
            codec.encode(&events, res, &mut buf).unwrap();
            std::hint::black_box(buf.len());
        });
        let dec = measure(1, samples, || {
            let (decoded, _) = codec.decode(&mut &encoded[..]).unwrap();
            std::hint::black_box(decoded.len());
        });
        table.row(&[
            format.to_string(),
            format!("{:.1}ms", enc.mean_s * 1e3),
            format!("{:.1}ms", dec.mean_s * 1e3),
            format!("{wire_bpe:.2}"),
            fmt_rate(enc.throughput(n as u64), "ev/s"),
            fmt_rate(dec.throughput(n as u64), "ev/s"),
        ]);
        for (op, stats) in [("encode", &enc), ("decode", &dec)] {
            json_lines.push(format!(
                "{{\"name\":\"{format}-{op}\",\"chunk\":{n},\"mean_s\":{:.6},\
                 \"std_s\":{:.6},\"min_s\":{:.6},\"throughput_ev_s\":{:.0},\
                 \"events_per_sec\":{:.0},\"bytes_moved_per_event\":{wire_bpe:.3}}}",
                stats.mean_s,
                stats.std_s,
                stats.min_s,
                stats.throughput(n as u64),
                stats.throughput(n as u64),
            ));
        }
    }
    println!("{}", table.render());
    println!("raw (packed u64) is the RAM-cache format of the Fig. 3 bench;");
    println!("EVT3 trades decode state for the smallest structured-scene wire size.\n");
    for line in &json_lines {
        println!("{line}");
    }
}
