//! Ablations beyond the paper's figures:
//!
//! 1. **engine design space** — coroutine channel capacity sweep, thread
//!    buffer-size × worker sweep, and the lock-free SPSC ring (§2.1's
//!    "approaches to eliminate locks"), all on the Fig. 3 workload;
//! 2. **filter-chain cost** — per-event cost of each pipeline op and of
//!    a realistic composed chain, bounding the L3 hot path.
//!
//! Run: `cargo bench --bench filter_ablation`

use aestream::aer::{Polarity, Resolution};
use aestream::bench::{fmt_rate, measure, Table};
use aestream::engine::EngineKind;
use aestream::pipeline::ops;
use aestream::pipeline::Pipeline;
use aestream::testutil::synthetic_events;

fn main() {
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let n: usize = if fast { 1 << 15 } else { 1 << 19 };
    let samples = if fast { 3 } else { 8 };
    let res = Resolution::DAVIS_346;
    let events = synthetic_events(n, res.width, res.height);

    // ------------------------------------------------ engine ablation
    println!("Engine design space on the checksum workload ({n} events)\n");
    let mut engines = Table::new(&["engine", "mean", "throughput"]);
    let kinds = [
        EngineKind::Sync,
        EngineKind::Coro,
        EngineKind::CoroChannel { channel_capacity: 1 },
        EngineKind::CoroChannel { channel_capacity: 256 },
        EngineKind::CoroChannel { channel_capacity: 4096 },
        EngineKind::Spsc { ring_capacity: 256 },
        EngineKind::Spsc { ring_capacity: 4096 },
        EngineKind::Threaded { buffer_size: 256, workers: 1 },
        EngineKind::Threaded { buffer_size: 4096, workers: 1 },
        EngineKind::Threaded { buffer_size: 4096, workers: 4 },
    ];
    for kind in kinds {
        let stats = measure(1, samples, || {
            std::hint::black_box(kind.run_checksum(&events));
        });
        engines.row(&[
            kind.label(),
            format!("{:.2}ms", stats.mean_s * 1e3),
            fmt_rate(stats.throughput(n as u64), "ev/s"),
        ]);
    }
    println!("{}", engines.render());

    // ------------------------------------------------ filter ablation
    println!("Per-event filter cost ({n} events)\n");
    let mut filters = Table::new(&["pipeline", "mean", "ns/event", "kept %"]);
    let mut cases: Vec<(&str, Pipeline)> = vec![
        ("identity", Pipeline::new()),
        ("polarity", Pipeline::new().then(ops::PolarityFilter::keep(Polarity::On))),
        ("downsample", Pipeline::new().then(ops::Downsample::new(2))),
        ("crop", Pipeline::new().then(ops::RoiCrop::new(50, 50, 200, 150))),
        ("refractory", Pipeline::new().then(ops::RefractoryFilter::new(res, 500))),
        ("denoise", Pipeline::new().then(ops::BackgroundActivityFilter::new(res, 5000))),
        (
            "full chain",
            Pipeline::new()
                .then(ops::BackgroundActivityFilter::new(res, 5000))
                .then(ops::RefractoryFilter::new(res, 500))
                .then(ops::RoiCrop::new(20, 20, 300, 220))
                .then(ops::Downsample::new(2)),
        ),
    ];
    for (name, pipeline) in &mut cases {
        let mut kept = 0usize;
        let stats = measure(1, samples, || {
            pipeline.reset();
            kept = pipeline.process(&events).len();
            std::hint::black_box(kept);
        });
        filters.row(&[
            name.to_string(),
            format!("{:.2}ms", stats.mean_s * 1e3),
            format!("{:.1}", stats.mean_s * 1e9 / n as f64),
            format!("{:.1}", 100.0 * kept as f64 / n as f64),
        ]);
    }
    println!("{}", filters.render());
}
