//! Fig. 4(C) reproduction: frames through the edge detector per scenario.
//!
//! The free-running device loop processes as many tensor frames as it
//! can while the producer paces the recording in real time; the paper
//! reports ~6.5×10⁴ frames for coroutines+CUDA-kernels vs ~5×10⁴ for
//! the conventional path over ~25 s (≈1.3×). This bench reports the
//! same series on the synthetic recording (scaled duration).
//!
//! Run: `cargo bench --bench fig4_frames`

use aestream::bench::Table;
use aestream::camera;
use aestream::coordinator::{run_scenario, ScenarioConfig};
use aestream::runtime::Device;

fn main() -> anyhow::Result<()> {
    let fast = std::env::var_os("AESTREAM_BENCH_FAST").is_some();
    let duration_us: u64 = if fast { 300_000 } else { 2_000_000 };
    let repeats = if fast { 1 } else { 3 };

    eprintln!("synthesizing {} ms recording…", duration_us / 1000);
    let recording = camera::paper_recording(duration_us, 42);
    eprintln!("{} events; opening device…", recording.len());
    let device = Device::open_default()?;

    let mut table =
        Table::new(&["scenario", "frames (mean)", "fps", "events", "exec ms", "prep ms"]);
    let mut frames_by_label: Vec<(String, f64)> = Vec::new();
    for cfg in ScenarioConfig::paper_four(1.0) {
        let mut frames = 0u64;
        let mut fps = 0.0;
        let mut exec_ns = 0u64;
        let mut prep_ns = 0u64;
        let mut events = 0u64;
        for _ in 0..repeats {
            let r = run_scenario(&device, &recording, &cfg)?;
            frames += r.frames;
            fps += r.fps();
            exec_ns += r.stats.exec_ns;
            prep_ns += r.host_prepare_ns;
            events = r.events;
        }
        let mean_frames = frames as f64 / repeats as f64;
        frames_by_label.push((cfg.label(), mean_frames));
        table.row(&[
            cfg.label(),
            format!("{mean_frames:.0}"),
            format!("{:.0}", fps / repeats as f64),
            events.to_string(),
            format!("{:.0}", exec_ns as f64 / repeats as f64 / 1e6),
            format!("{:.2}", prep_ns as f64 / repeats as f64 / 1e6),
        ]);
    }
    println!("Fig. 4(C) — frames through the edge detector\n");
    println!("{}", table.render());

    let get = |l: &str| frames_by_label.iter().find(|r| r.0 == l).unwrap().1;
    println!(
        "coro+sparse vs threads+dense: {:.2}× frames (paper: ~1.3×, 6.5e4 vs 5e4)",
        get("coro+sparse") / get("threads+dense")
    );
    println!(
        "coro vs threads at fixed transfer: dense {:.2}×, sparse {:.2}×",
        get("coro+dense") / get("threads+dense"),
        get("coro+sparse") / get("threads+sparse")
    );
    Ok(())
}
